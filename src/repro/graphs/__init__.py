"""Bipartite graph instance generation — the paper's experimental sets."""
from .generators import (
    INSTANCE_FAMILIES,
    banded,
    comb_chain,
    community_graph,
    grid_graph,
    instance_sets,
    kron_graph,
    random_bipartite,
    scaled_free,
)
from .mtx import load_mtx, mtx_fixture

__all__ = ["random_bipartite", "kron_graph", "grid_graph", "scaled_free",
           "banded", "community_graph", "comb_chain", "instance_sets",
           "INSTANCE_FAMILIES", "load_mtx", "mtx_fixture"]
