"""Bipartite graph instance generation — the paper's experimental sets."""
from .generators import (
    banded,
    grid_graph,
    instance_sets,
    kron_graph,
    random_bipartite,
    scaled_free,
)

__all__ = ["random_bipartite", "kron_graph", "grid_graph", "scaled_free",
           "banded", "instance_sets"]
