"""Synthetic bipartite instance families standing in for the UFL collection.

The paper evaluates on 70 UFL sparse matrices spanning road networks
(italy_osm, europe_osm), Delaunay meshes, social/web graphs (soc-LiveJournal,
wikipedia), Kronecker graphs (kron_g500) and linear-programming matrices, plus
randomly row/column-permuted copies (RCP sets) that destroy locality and make
the problems harder for augmenting-path algorithms.

Offline we reproduce the same *structure classes*:

* ``random_bipartite`` — Erdos-Renyi-like sparse matrices (LP-style),
* ``kron_graph``       — RMAT/Kronecker power-law (kron_g500-style),
* ``grid_graph``       — 2-D mesh adjacency (road/Delaunay-style: long paths),
* ``scaled_free``      — heavy-tail degree columns (web/social-style),
* ``banded``           — banded LP/PDE matrices,
* ``community_graph``  — stochastic-block bipartite (clustered social-style),
* ``comb_chain``       — adversarial single long augmenting path (the BFS
  worst case: one phase whose search tree is ``O(n)`` levels deep),

``BipartiteCSR.permuted()`` provides the RCP transform, and
:func:`instance_sets` bundles one instance per family at every scale
(``rcp=True`` adds the permuted twins) so per-family gates compare like to
like across scales.  Real UFL/SuiteSparse matrices drop in through
:func:`repro.graphs.mtx.load_mtx`.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.csr import BipartiteCSR


def random_bipartite(nc: int, nr: int, avg_deg: float, seed: int = 0,
                     pad_to=None) -> BipartiteCSR:
    """Uniform random bipartite graph with ~avg_deg edges per column."""
    rng = np.random.default_rng(seed)
    nnz = int(nc * avg_deg)
    cols = rng.integers(0, nc, size=nnz)
    rows = rng.integers(0, nr, size=nnz)
    return BipartiteCSR.from_edges(cols, rows, nc, nr, pad_to=pad_to)


def kron_graph(scale: int, edge_factor: int = 8, seed: int = 0,
               pad_to=None) -> BipartiteCSR:
    """RMAT/Kronecker bipartite graph (Graph500 parameters a,b,c=.57,.19,.19)."""
    n = 1 << scale
    nnz = n * edge_factor
    rng = np.random.default_rng(seed)
    a, b, c = 0.57, 0.19, 0.19
    cols = np.zeros(nnz, dtype=np.int64)
    rows = np.zeros(nnz, dtype=np.int64)
    for bit in range(scale):
        u = rng.random(nnz)
        # quadrant probabilities: (0,0)=a, (0,1)=b, (1,0)=c, (1,1)=d
        cbit = (u >= a + b).astype(np.int64)
        rbit = ((u >= a) & (u < a + b) | (u >= a + b + c)).astype(np.int64)
        cols |= cbit << bit
        rows |= rbit << bit
    return BipartiteCSR.from_edges(cols, rows, n, n, pad_to=pad_to)


def grid_graph(side: int, pad_to=None) -> BipartiteCSR:
    """Bipartite double cover of a 2-D grid — long augmenting paths, like the
    paper's road/Delaunay instances (the hard cases for BFS matchers)."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    cols_l: List[np.ndarray] = [np.arange(n)]
    rows_l: List[np.ndarray] = [np.arange(n)]          # self edge (diagonal)
    right_c = idx[:, :-1].ravel()
    right_r = idx[:, 1:].ravel()
    down_c = idx[:-1, :].ravel()
    down_r = idx[1:, :].ravel()
    cols_l += [right_c, right_r, down_c, down_r]
    rows_l += [right_r, right_c, down_r, down_c]
    cols = np.concatenate(cols_l)
    rows = np.concatenate(rows_l)
    return BipartiteCSR.from_edges(cols, rows, n, n, pad_to=pad_to)


def scaled_free(nc: int, nr: int, avg_deg: float, alpha: float = 1.8,
                seed: int = 0, pad_to=None) -> BipartiteCSR:
    """Power-law column degrees (web/social-matrix style)."""
    rng = np.random.default_rng(seed)
    w = rng.zipf(alpha, size=nc).astype(np.float64)
    w = np.minimum(w, nr // 2)
    w *= (nc * avg_deg) / w.sum()
    degs = np.maximum(1, rng.poisson(w)).astype(np.int64)
    cols = np.repeat(np.arange(nc, dtype=np.int64), degs)
    rows = rng.integers(0, nr, size=int(degs.sum()))
    return BipartiteCSR.from_edges(cols, rows, nc, nr, pad_to=pad_to)


def community_graph(nc: int, nr: int, blocks: int = 8, avg_deg: float = 4.0,
                    p_in: float = 0.9, seed: int = 0,
                    pad_to=None) -> BipartiteCSR:
    """Bipartite stochastic-block graph (community-structured social-style).

    Columns and rows are split into ``blocks`` aligned groups; each edge
    stays inside its column's row-group with probability ``p_in`` and lands
    uniformly at random otherwise.  RCP permutation destroys exactly this
    block locality, which is what makes the paper's RCP sets harder.
    """
    assert 1 <= blocks <= min(nc, nr), (blocks, nc, nr)
    rng = np.random.default_rng(seed)
    nnz = int(nc * avg_deg)
    cols = rng.integers(0, nc, size=nnz)
    cblk = cols * blocks // nc
    r_lo = cblk * nr // blocks
    r_hi = (cblk + 1) * nr // blocks
    row_in = r_lo + (rng.random(nnz) * (r_hi - r_lo)).astype(np.int64)
    row_out = rng.integers(0, nr, size=nnz)
    rows = np.where(rng.random(nnz) < p_in, row_in, row_out)
    return BipartiteCSR.from_edges(cols, rows, nc, nr, pad_to=pad_to)


def comb_chain(length: int, teeth: int = 0, seed: int = 0,
               pad_to=None) -> BipartiteCSR:
    """Adversarial long-augmenting-path "comb" (worst case for BFS matchers).

    A chain of ``length+1`` columns over ``length+1`` spine rows:

    * column 0 sees rows {0, 1}; column i (0<i<length) sees {i, i+1};
    * column ``length`` sees only row 0.

    The sequential cheap/greedy init (which always picks the lowest free row)
    matches column i to row i, leaving column ``length`` unmatched — and the
    *only* augmenting path left is c_len→r_0→c_0→r_1→…→c_{len-1}→r_len, of
    length ``2*length+1``.  One BFS phase must therefore run ``O(length)``
    level iterations: the deep-search stressor the paper's road instances
    approximate.  ``teeth`` extra free rows (ids above the spine, so the
    greedy init ignores them) inflate the pull-side degree mass the
    direction-optimizing heuristic reads; they attach only to columns in the
    last quarter of the spine — a free tooth row on an early column would
    short-circuit the alternating tree and collapse the BFS depth, so this
    keeps the shortest augmenting path at ``>= 3*length/4`` levels.
    """
    assert length >= 1
    cols_l = [np.repeat(np.arange(length, dtype=np.int64), 2),
              np.asarray([length], dtype=np.int64)]
    spine = np.arange(length, dtype=np.int64)
    rows_l = [np.stack([spine, spine + 1], axis=1).ravel(),
              np.asarray([0], dtype=np.int64)]
    nr = length + 1 + teeth
    if teeth:
        rng = np.random.default_rng(seed)
        tooth_deg = 4
        lo = max(0, (3 * length) // 4)
        cols_l.append(rng.integers(lo, length, size=teeth * tooth_deg))
        rows_l.append(np.repeat(np.arange(length + 1, nr, dtype=np.int64),
                                tooth_deg))
    return BipartiteCSR.from_edges(np.concatenate(cols_l),
                                   np.concatenate(rows_l),
                                   length + 1, nr, pad_to=pad_to)


# one parameter tuple per scale; every scale instantiates the SAME families
# (keys below) so per-family gate rows compare like to like across scales.
# n = square-family vertex count, rect = (nc, nr), kron = log2 scale,
# grid = side, comb = chain length (BFS depth ~ 2*comb).
_SCALE_PARAMS = {
    "mini":  dict(n=256, deg=4.0, rect=(192, 320), kron=7, grid=12,
                  free_deg=5.0, sparse_deg=2.5, band=3, blocks=4, comb=64,
                  teeth=16),
    "tiny":  dict(n=1024, deg=4.0, rect=(768, 1280), kron=10, grid=24,
                  free_deg=6.0, sparse_deg=2.5, band=4, blocks=8, comb=192,
                  teeth=48),
    "small": dict(n=16384, deg=5.0, rect=(12288, 20480), kron=14, grid=96,
                  free_deg=8.0, sparse_deg=2.5, band=6, blocks=16, comb=2048,
                  teeth=512),
    "large": dict(n=1 << 18, deg=5.0, rect=(3 << 16, 5 << 16), kron=17,
                  grid=384, free_deg=8.0, sparse_deg=2.5, band=8, blocks=32,
                  comb=8192, teeth=2048),
}

INSTANCE_FAMILIES = ("rand", "sparse", "rand_rect", "band", "kron", "grid",
                     "free", "community", "comb")


def instance_sets(scale: str = "small", rcp: bool = False,
                  rcp_seed: int = 13) -> Dict[str, BipartiteCSR]:
    """Named instance suite: one instance per family, same families at every
    scale (:data:`INSTANCE_FAMILIES`).

    ``scale``: "mini" (fast unit tests), "tiny" (tests), "small" (CI
    benchmarks), "large" (full bench).  ``rcp=True`` appends a
    ``<family>_rcp`` row/column-permuted twin per family — the paper's RCP
    sets, which destroy locality without changing the matching number.
    """
    if scale not in _SCALE_PARAMS:
        raise ValueError(scale)
    p = _SCALE_PARAMS[scale]
    n = p["n"]
    out = {
        "rand": random_bipartite(n, n, p["deg"], seed=1),
        "sparse": random_bipartite(n, n, p["sparse_deg"], seed=5),
        "rand_rect": random_bipartite(*p["rect"], p["deg"] + 1.0, seed=2),
        "band": banded(n, band=p["band"], density=0.5, seed=6),
        "kron": kron_graph(p["kron"], 8, seed=3),
        "grid": grid_graph(p["grid"]),
        "free": scaled_free(n, n, p["free_deg"], seed=4),
        "community": community_graph(n, n, blocks=p["blocks"],
                                     avg_deg=p["deg"], seed=7),
        "comb": comb_chain(p["comb"], teeth=p["teeth"], seed=8),
    }
    if rcp:
        out.update({f"{k}_rcp": g.permuted(rcp_seed)
                    for k, g in tuple(out.items())})
    return out


def banded(n: int, band: int = 5, density: float = 0.6, seed: int = 0,
           pad_to=None) -> BipartiteCSR:
    """Banded matrix (LP/PDE-style UFL class): edges within |c-r| <= band."""
    rng = np.random.default_rng(seed)
    offs = np.arange(-band, band + 1)
    cols_l, rows_l = [np.arange(n)], [np.arange(n)]   # keep the diagonal
    for off in offs:
        if off == 0:
            continue
        c = np.arange(max(0, -off), min(n, n - off))
        r = c + off
        keep = rng.random(c.shape[0]) < density
        cols_l.append(c[keep])
        rows_l.append(r[keep])
    return BipartiteCSR.from_edges(np.concatenate(cols_l),
                                   np.concatenate(rows_l), n, n,
                                   pad_to=pad_to)
