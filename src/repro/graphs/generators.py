"""Synthetic bipartite instance families standing in for the UFL collection.

The paper evaluates on 70 UFL sparse matrices spanning road networks
(italy_osm, europe_osm), Delaunay meshes, social/web graphs (soc-LiveJournal,
wikipedia), Kronecker graphs (kron_g500) and linear-programming matrices, plus
randomly row/column-permuted copies (RCP sets) that destroy locality and make
the problems harder for augmenting-path algorithms.

Offline we reproduce the same *structure classes*:

* ``random_bipartite`` — Erdos-Renyi-like sparse matrices (LP-style),
* ``kron_graph``       — RMAT/Kronecker power-law (kron_g500-style),
* ``grid_graph``       — 2-D mesh adjacency (road/Delaunay-style: long paths),
* ``scaled_free``      — heavy-tail degree columns (web/social-style),

and ``BipartiteCSR.permuted()`` provides the RCP transform.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.csr import BipartiteCSR


def random_bipartite(nc: int, nr: int, avg_deg: float, seed: int = 0,
                     pad_to=None) -> BipartiteCSR:
    """Uniform random bipartite graph with ~avg_deg edges per column."""
    rng = np.random.default_rng(seed)
    nnz = int(nc * avg_deg)
    cols = rng.integers(0, nc, size=nnz)
    rows = rng.integers(0, nr, size=nnz)
    return BipartiteCSR.from_edges(cols, rows, nc, nr, pad_to=pad_to)


def kron_graph(scale: int, edge_factor: int = 8, seed: int = 0,
               pad_to=None) -> BipartiteCSR:
    """RMAT/Kronecker bipartite graph (Graph500 parameters a,b,c=.57,.19,.19)."""
    n = 1 << scale
    nnz = n * edge_factor
    rng = np.random.default_rng(seed)
    a, b, c = 0.57, 0.19, 0.19
    cols = np.zeros(nnz, dtype=np.int64)
    rows = np.zeros(nnz, dtype=np.int64)
    for bit in range(scale):
        u = rng.random(nnz)
        # quadrant probabilities: (0,0)=a, (0,1)=b, (1,0)=c, (1,1)=d
        cbit = (u >= a + b).astype(np.int64)
        rbit = ((u >= a) & (u < a + b) | (u >= a + b + c)).astype(np.int64)
        cols |= cbit << bit
        rows |= rbit << bit
    return BipartiteCSR.from_edges(cols, rows, n, n, pad_to=pad_to)


def grid_graph(side: int, pad_to=None) -> BipartiteCSR:
    """Bipartite double cover of a 2-D grid — long augmenting paths, like the
    paper's road/Delaunay instances (the hard cases for BFS matchers)."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    cols_l: List[np.ndarray] = [np.arange(n)]
    rows_l: List[np.ndarray] = [np.arange(n)]          # self edge (diagonal)
    right_c = idx[:, :-1].ravel()
    right_r = idx[:, 1:].ravel()
    down_c = idx[:-1, :].ravel()
    down_r = idx[1:, :].ravel()
    cols_l += [right_c, right_r, down_c, down_r]
    rows_l += [right_r, right_c, down_r, down_c]
    cols = np.concatenate(cols_l)
    rows = np.concatenate(rows_l)
    return BipartiteCSR.from_edges(cols, rows, n, n, pad_to=pad_to)


def scaled_free(nc: int, nr: int, avg_deg: float, alpha: float = 1.8,
                seed: int = 0, pad_to=None) -> BipartiteCSR:
    """Power-law column degrees (web/social-matrix style)."""
    rng = np.random.default_rng(seed)
    w = rng.zipf(alpha, size=nc).astype(np.float64)
    w = np.minimum(w, nr // 2)
    w *= (nc * avg_deg) / w.sum()
    degs = np.maximum(1, rng.poisson(w)).astype(np.int64)
    cols = np.repeat(np.arange(nc, dtype=np.int64), degs)
    rows = rng.integers(0, nr, size=int(degs.sum()))
    return BipartiteCSR.from_edges(cols, rows, nc, nr, pad_to=pad_to)


def instance_sets(scale: str = "small") -> Dict[str, BipartiteCSR]:
    """Named instance suite (original set; use .permuted() for the RCP set).

    ``scale``: "tiny" (tests), "small" (CI benchmarks), "large" (full bench).
    """
    if scale == "tiny":
        return {
            "rand_1k": random_bipartite(1024, 1024, 4.0, seed=1),
            "band_1k": banded(1024, band=4, density=0.5, seed=6),
            "rand_rect": random_bipartite(768, 1280, 5.0, seed=2),
            "kron_10": kron_graph(10, 8, seed=3),
            "grid_24": grid_graph(24),
            "free_1k": scaled_free(1024, 1024, 6.0, seed=4),
        }
    if scale == "small":
        return {
            "rand_16k": random_bipartite(16384, 16384, 5.0, seed=1),
            "band_16k": banded(16384, band=6, density=0.5, seed=6),
            "rand_rect16k": random_bipartite(12288, 20480, 6.0, seed=2),
            "kron_14": kron_graph(14, 8, seed=3),
            "grid_96": grid_graph(96),
            "free_16k": scaled_free(16384, 16384, 8.0, seed=4),
            "sparse_16k": random_bipartite(16384, 16384, 2.5, seed=5),
        }
    if scale == "large":
        return {
            "rand_262k": random_bipartite(1 << 18, 1 << 18, 5.0, seed=1),
            "kron_17": kron_graph(17, 8, seed=3),
            "grid_384": grid_graph(384),
            "free_262k": scaled_free(1 << 18, 1 << 18, 8.0, seed=4),
            "sparse_262k": random_bipartite(1 << 18, 1 << 18, 2.5, seed=5),
        }
    raise ValueError(scale)


def banded(n: int, band: int = 5, density: float = 0.6, seed: int = 0,
           pad_to=None) -> BipartiteCSR:
    """Banded matrix (LP/PDE-style UFL class): edges within |c-r| <= band."""
    rng = np.random.default_rng(seed)
    offs = np.arange(-band, band + 1)
    cols_l, rows_l = [np.arange(n)], [np.arange(n)]   # keep the diagonal
    for off in offs:
        if off == 0:
            continue
        c = np.arange(max(0, -off), min(n, n - off))
        r = c + off
        keep = rng.random(c.shape[0]) < density
        cols_l.append(c[keep])
        rows_l.append(r[keep])
    return BipartiteCSR.from_edges(np.concatenate(cols_l),
                                   np.concatenate(rows_l), n, n,
                                   pad_to=pad_to)
