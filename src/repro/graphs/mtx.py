"""Matrix Market loader — real SuiteSparse/UFL instances drop into the corpus.

The paper's evaluation set is 70 UFL sparse matrices (plus RCP-permuted
copies).  :func:`load_mtx` turns any ``.mtx`` file into a
:class:`~repro.core.csr.BipartiteCSR`: matrix columns become column vertices,
matrix rows become row vertices (the paper matches the columns of A onto its
rows), values are ignored — only the sparsity pattern matters for cardinality
matching.  Explicit stored zeros are kept as edges, matching how the UFL
pattern collection treats them.

``fixtures/`` holds one tiny committed instance so the loader (and the
corpus plumbing downstream of it) is exercised in tier-1 tests without
network access; pointing :func:`load_mtx` at a downloaded SuiteSparse file
is the production path.
"""
from __future__ import annotations

import os

from repro.core.csr import BipartiteCSR

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")


def load_mtx(path: str, pad_to=None) -> BipartiteCSR:
    """Load a Matrix Market (coordinate or array) file as a bipartite graph.

    Symmetric storage is expanded by scipy, so a symmetric UFL matrix yields
    the same edge set as its ``general`` form; duplicate entries collapse in
    ``from_edges``.
    """
    from scipy.io import mmread

    m = mmread(path).tocoo()
    nr, nc = (int(s) for s in m.shape)
    return BipartiteCSR.from_edges(m.col, m.row, nc, nr, pad_to=pad_to)


def mtx_fixture(name: str = "ufl_tiny", pad_to=None) -> BipartiteCSR:
    """A committed fixture instance from ``fixtures/<name>.mtx``."""
    return load_mtx(os.path.join(FIXTURE_DIR, f"{name}.mtx"), pad_to=pad_to)
