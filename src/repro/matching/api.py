"""The composable matcher facade and vmap-batched ``match_many``.

:class:`Matcher` binds a :class:`MatcherConfig` variant plus a named warm
start and exposes a pure, jit-closed ``run(graph, state) -> MatchState``.
When no state is passed, warm-start initialization and the APFB/APsB solve
trace into ONE compiled program — there is no host transfer between init and
solve (the property the paper's whole design argues for).  Compiled programs
live in the explicit compile cache keyed on (bucket shape, config, warm
start), so repeated calls on the same size bucket dispatch immediately.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from .cache import compile_cache_key, get_compiled
from .config import MatcherConfig
from .device_csr import DeviceCSR
from .solve import make_solver
from .state import MatchState, MatchStats, empty_like_graph
from .warmstart import get_warm_start, warm_start_version


class Matcher:
    """A paper variant + warm start, compiled per size bucket.

    >>> m = Matcher(MatcherConfig(algo="apfb"), warm_start="karp_sipser")
    >>> state = m.run(graph)            # init + solve, one device program
    >>> int(state.cardinality)          # first (and only) host sync
    """

    def __init__(self, config: MatcherConfig = MatcherConfig(),
                 warm_start: str = "none"):
        # canonical(): the pallas_interpret=None auto marker resolves to the
        # backend's concrete compilation mode here, so every compile-cache
        # key built from self.config carries the real interpret bool.
        self.config = config.canonical()
        self.warm_start = warm_start
        get_warm_start(warm_start)      # fail fast on unknown names

    @staticmethod
    def _check_state(graph: DeviceCSR, state: MatchState) -> None:
        """A state sized for a different graph would silently corrupt the
        BFS (clamped gathers); fail loudly at trace time instead."""
        assert (state.cmatch.shape[-1] == graph.nc + 1
                and state.rmatch.shape[-1] == graph.nr + 1), (
            f"MatchState sized {(state.cmatch.shape[-1] - 1,)} x "
            f"{(state.rmatch.shape[-1] - 1,)} does not fit graph bucket "
            f"({graph.nc}, {graph.nr})")

    # -- pure pytree functions (safe to jit/vmap/compose) --------------------
    def init(self, graph: DeviceCSR, state: Optional[MatchState] = None
             ) -> MatchState:
        """Warm-start-initialized state (no solve).

        Pure in its pytree arguments; the eager path dispatches through the
        compile cache, and under an outer ``jit`` it simply inlines.
        """
        if state is None:
            state = empty_like_graph(graph)
        key = compile_cache_key(graph.bucket_key, None,
                                self._cache_tag(True), "init")
        return get_compiled(key, lambda: self._init_pure)(graph, state)

    def _init_pure(self, graph: DeviceCSR, state: MatchState) -> MatchState:
        self._check_state(graph, state)
        cm, rm = get_warm_start(self.warm_start)(
            graph.ecol, graph.cadj, state.cmatch, state.rmatch)
        return dataclasses.replace(state, cmatch=cm, rmatch=rm)

    def solve(self, graph: DeviceCSR, state: MatchState) -> MatchState:
        """Run the solver from ``state`` (pure; no warm start applied)."""
        self._check_state(graph, state)
        kw = {}
        if self.config.adaptive_frontier or self.config.dirop:
            kw["cxadj"] = graph.cxadj
        if self.config.dirop:
            if not graph.has_csc:
                raise ValueError(
                    "MatcherConfig(dirop=True) needs the CSC mirror; build "
                    "it once with graph.with_csc() (serving admission does "
                    "this automatically for dirop configs)")
            kw.update(rxadj=graph.rxadj, radj=graph.radj, erow=graph.erow)
        cm, rm, phases, fb, cert = make_solver(self.config)(
            graph.ecol, graph.cadj, state.cmatch, state.rmatch, **kw)
        return MatchState(cmatch=cm, rmatch=rm,
                          phases=state.phases + phases,
                          fallbacks=state.fallbacks + fb,
                          certified=cert)

    def _cache_tag(self, cold: bool):
        """Warm-start identity for the compile cache; versioned so that
        re-registering a name invalidates programs built from the old fn."""
        if not cold:
            return "<resume>"
        return (self.warm_start, warm_start_version(self.warm_start))

    # -- compiled entry points ------------------------------------------------
    def run(self, graph: DeviceCSR, state: Optional[MatchState] = None
            ) -> MatchState:
        """Maximum matching on device.

        ``state=None``: warm start + solve fused in one program.  With an
        explicit ``state`` (e.g. resuming after graph updates) the warm start
        is skipped and the solver continues from it.  Pure in its pytree
        arguments — calling it under an outer ``jax.jit`` inlines the whole
        matcher into the caller's program.
        """
        assert not graph.batch_shape, \
            "run() takes a single graph; use run_many for a stacked DeviceCSR"
        cold = state is None
        if cold:
            state = empty_like_graph(graph)
        ws = self._cache_tag(cold)
        key = compile_cache_key(graph.bucket_key, self.config, ws, "run")

        def build():
            if cold:
                # _init_pure, not init: going through the public entry inside
                # this build would register a second ("init") cache entry at
                # trace time (AOT warmup counts on one program per entry).
                return lambda g, s: self.solve(g, self._init_pure(g, s))
            return self.solve

        return get_compiled(key, build)(graph, state)

    def run_many(self, graphs: DeviceCSR,
                 states: Optional[MatchState] = None) -> MatchState:
        """Batched matching over a stacked same-bucket ``DeviceCSR``.

        One ``vmap``-compiled program solves the whole batch per dispatch —
        the serving path for many concurrent matching requests.
        """
        if self.config.adaptive_frontier:
            # vmap turns the per-level lax.cond into a select: every graph
            # would run BOTH the dense and the compact sweep each level — a
            # strict pessimization, so refuse rather than quietly regress.
            # (dirop is allowed through: the serving layer batches dirop
            # requests and correctness is unaffected, but the same
            # cond->select cost applies — see docs/architecture.md.)
            raise ValueError(
                "adaptive_frontier composes with per-graph run() only; "
                "under run_many's vmap both sweeps would execute each level")
        assert graphs.batch_shape, "run_many expects a stacked DeviceCSR"
        cold = states is None
        if cold:
            states = empty_like_graph(graphs)
        ws = self._cache_tag(cold)
        key = compile_cache_key(graphs.bucket_key, self.config, ws,
                                "run_many")

        def build():
            if cold:
                one = lambda g, s: self.solve(g, self._init_pure(g, s))  # noqa: E731
            else:
                one = self.solve
            return jax.vmap(one)

        return get_compiled(key, build)(graphs, states)

    def stats(self, state: MatchState) -> MatchStats:
        """Device-scalar stats labelled with this matcher's variant name."""
        return MatchStats.of(state, self.config.name)


def match_many(graphs: DeviceCSR, config: MatcherConfig = MatcherConfig(),
               warm_start: str = "cheap",
               states: Optional[MatchState] = None) -> MatchState:
    """Functional alias: ``Matcher(config, warm_start).run_many(graphs)``."""
    return Matcher(config, warm_start).run_many(graphs, states)


def maximum_matching_device(graph: DeviceCSR,
                            config: MatcherConfig = MatcherConfig(),
                            warm_start: str = "none") -> MatchState:
    """Single-graph device-resident matching (state in, state out)."""
    return Matcher(config, warm_start).run(graph)
