"""Pure JAX solver for the paper's GPU matching algorithms (APFB / APsB).

Mapping from the paper's CUDA kernels to TPU-friendly vector ops
----------------------------------------------------------------
The paper launches one CUDA thread per column (MT) or a constant thread grid
(CT), each walking its CSR adjacency with benign write races.  Here a BFS
level is a single *edge-parallel* vector operation over all ``nnz`` edges:

* the per-thread race "first writer wins" becomes a deterministic
  ``min``-merge (lowest proposing column wins) — same semantics class the
  paper relies on, but reproducible.  Three interchangeable sweeps produce
  the identical per-row winner vector: the jnp path (proposals + XLA
  scatter), the legacy Pallas path (proposal kernel + XLA scatter) and the
  fused Pallas path (winner accumulator merged inside the kernel, no (nnz,)
  intermediate — the default when ``use_pallas``);
* beyond-paper, ``adaptive_frontier`` tracks the frontier size each level
  and swaps the dense O(nnz) sweep for a compact column-gather sweep
  (O(cap·dmax)) whenever the frontier is small enough, with a runtime
  fallback that keeps the result bit-identical;
* beyond-paper, ``dirop`` is the direction-optimizing engine: each level a
  Beamer-style heuristic compares the frontier's outgoing-edge count
  against the unreached rows' incoming-edge count (both O(n) degree sums
  off ``cxadj``/``rxadj``) and ``lax.cond``-dispatches either the push
  sweep or a *pull* sweep over the CSC mirror — a compact row-gather
  (O(cap·dmax)) on the jnp path, the tile-skipping
  ``frontier_expand_pull`` kernel on the Pallas path.  The proposal
  predicate factors into a column side and a row side, so pull and push
  enumerate the same proposals and the min-merge winner is bit-identical
  whichever direction ran — the heuristic is a pure performance decision;
* ``ALTERNATE`` (Alg. 3) walks all augmenting paths in lock-step inside a
  ``lax.while_loop``; the paper's line-8 predecessor check is a vector mask;
* ``FIXMATCHING`` is the paper's repair pass, applied in both directions so
  every phase ends with a *valid* (possibly sub-maximal) matching;
* a cardinality guard re-runs ``ALTERNATE`` with a single walker on the
  phase-start snapshot if the speculative phase failed to gain — this bounds
  the outer loop by ``nc`` phases (engineering safeguard; the speculative
  phase almost always gains, see benchmarks).

State layout (all int32, one sentinel slot at the end of every array):
``bfs``  (nc+1,)  BFS level per column; L0-1==1 means unvisited, L0==2 roots.
``root`` (nc+1,)  root column of the BFS tree (GPUBFS-WR only).
``pred`` (nr+1,)  predecessor column of a row in the BFS forest.
``cmatch`` (nc+1,) / ``rmatch`` (nr+1,) the matching; -1 unmatched,
rmatch==-2 flags an augmenting-path endpoint (paper's convention).

Everything here is a *pure function of its array arguments*: the problem
sizes are derived from the (static) array shapes at trace time, so the same
function composes under ``jax.jit``, ``jax.vmap`` (via :func:`make_solver`)
and the warm-start registry with zero host transfers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# the one definition of the TPU lane width (floor for any edge tile) lives
# next to the kernels that tile over it
from repro.kernels.frontier_expand import LANE

from .config import MatcherConfig

L0 = jnp.int32(2)            # paper's suggested start level (keeps bfs positive)
UNVISITED = jnp.int32(1)     # L0 - 1
FOUND = jnp.int32(0)         # L0 - 2 : root's augmenting path already found (WR)
NEG = jnp.int32(-(2**30))    # sentinel level: never active, never unvisited
IINF = jnp.int32(2**30)      # scatter-min identity


def scatter_min(n: int, index, values):
    """Deterministic "first writer wins": per-slot min over proposals.

    ``index`` may use slot ``n`` as the discard sentinel; the sentinel slot is
    reset to the identity so it never reads back as a winner.
    """
    out = jnp.full(n + 1, IINF, jnp.int32).at[index].min(values)
    return out.at[n].set(IINF)


def level0_state(cmatch):
    """BFS state at the paper's start level for a given matching: ``bfs``
    (unmatched columns are L0 roots, matched UNVISITED, sentinel NEG) and
    ``root`` (own index if root).  The exact init ``phase_bfs`` performs —
    shared with the kernel benches/tests so their probe states cannot drift
    from what the solver actually sweeps.
    """
    nc = cmatch.shape[0] - 1
    cols = jnp.arange(nc + 1, dtype=jnp.int32)
    bfs = jnp.where(cmatch >= 0, UNVISITED, L0).at[nc].set(NEG)
    root = jnp.where(cmatch >= 0, jnp.int32(nc), cols)
    return bfs, root


def default_block_edges(nnz_pad: int, schedule: str) -> int:
    """Edge-tile size for the Pallas frontier kernel.

    CT: big fixed tile (constant "thread" count, coarse grain);
    MT: one-edge-per-lane fine grain -> smaller tiles.

    Never degenerate: the kernel wrappers pad the edge arrays up to a tile
    multiple, so the tile no longer has to divide ``nnz_pad`` (the old
    ``gcd`` collapsed to 1-lane tiles on prime edge counts).  The result is
    always a multiple of the 128-lane width, floor 128.
    """
    desired = 4096 if schedule == "ct" else 512
    return min(desired, -(-nnz_pad // LANE) * LANE)


# ---------------------------------------------------------------------------
# BFS level expansion — the paper's Algorithms 2 (GPUBFS) and 4 (GPUBFS-WR)
# ---------------------------------------------------------------------------
def _winner_full(ecol, cadj, bfs, root, rmatch, level, nr, *, use_pallas: bool,
                 pallas_fused: bool, block_edges: int,
                 interpret: Optional[bool]):
    """Dense O(nnz) sweep -> per-row winner vector (nr+1,)."""
    if use_pallas and pallas_fused:
        from repro.kernels.frontier_expand.ops import frontier_expand_fused
        return frontier_expand_fused(ecol, cadj, bfs, root, rmatch, level,
                                     block_edges=block_edges,
                                     interpret=interpret)
    if use_pallas:
        from repro.kernels.frontier_expand.ops import frontier_expand
        prop = frontier_expand(ecol, cadj, bfs, root, rmatch, level,
                               block_edges=block_edges, interpret=interpret)
    else:
        target = _proposal_mask(ecol, cadj, bfs, root, rmatch, level)
        prop = jnp.where(target, ecol, IINF)          # per-edge proposal
    row_ix = jnp.where(prop < IINF, cadj, nr)
    return scatter_min(nr, row_ix, prop)


def _proposal_mask(ecol, cadj, bfs, root, rmatch, level):
    """Per-edge proposal predicate — the ONE formula the kernels tile
    (shared so jnp-vs-Pallas parity cannot drift; the jnp oracle in
    kernels/frontier_expand/ref.py stays an independent copy on purpose)."""
    from repro.kernels.frontier_expand.frontier_expand import _proposals
    return _proposals(level, ecol, cadj, bfs, root, rmatch)


def _unreached_rows(bfs, rmatch):
    """The (nr,) mask of rows still reachable this phase — the row side of
    the proposal predicate: unmatched-and-not-yet-endpoint rows, or rows
    whose matched column is still UNVISITED.  Winners are IINF everywhere
    else, which is what makes a pull sweep restricted to these rows exact.
    """
    nc = bfs.shape[0] - 1
    rm = rmatch[:-1]
    return (rm == -1) | ((rm >= 0) & (bfs[jnp.clip(rm, 0, nc)] == UNVISITED))


def _winner_pull_compact(rxadj, radj, bfs, root, rmatch, level, nr,
                         unreached, *, cap: int, dmax: int):
    """Compact pull sweep: gather the unreached rows' adjacency via the CSC
    mirror, O(cap·dmax) instead of O(nnz).

    ``unreached`` is :func:`_unreached_rows` (passed in, not recomputed —
    XLA cannot CSE across the ``lax.cond`` boundary).  Only called when the
    eligibility guard holds (every unreached row gathered, every of its
    edges scanned), in which case each row's min over its proposing columns
    is exactly the dense sweep's min-merge winner — bit-identical.
    """
    nc = bfs.shape[0] - 1
    nnz_pad = radj.shape[0]
    # the column side of the proposal predicate, for every column at once
    colok = bfs == level                                         # (nc+1,)
    if root is not None:
        colok &= bfs[jnp.clip(root, 0, nc)] >= UNVISITED
    rows = jnp.nonzero(unreached, size=cap, fill_value=nr)[0]    # (cap,)
    starts = rxadj[jnp.minimum(rows, nr)]
    ends = rxadj[jnp.minimum(rows + 1, nr)]                      # fill -> deg 0
    offs = jnp.arange(dmax, dtype=jnp.int32)
    eidx = starts[:, None] + offs[None, :]                       # (cap, dmax)
    valid = offs[None, :] < (ends - starts)[:, None]
    cols = jnp.where(valid, radj[jnp.clip(eidx, 0, nnz_pad - 1)],
                     jnp.int32(nc))
    ok = valid & colok[cols]               # colok[nc] is False (bfs NEG)
    win_rows = jnp.min(jnp.where(ok, cols, IINF), axis=1)        # (cap,)
    return scatter_min(nr, jnp.minimum(rows, nr), win_rows)


def _winner_pull_stream(radj, erow, bfs, root, rmatch, level, nr, *,
                        use_pallas: bool, block_edges: int,
                        interpret: Optional[bool]):
    """Streaming pull sweep over the (possibly sharded) CSC edge list.

    On the Pallas path this is ``frontier_expand_pull`` — row-sorted tiles
    whose in-VMEM merge skips when the tile proposes nothing.  The jnp form
    is the dense sweep on the permuted arrays (no asymptotic win — it
    exists so the sharded jnp path can follow the same direction decision
    with bit-identical winners).
    """
    if use_pallas:
        from repro.kernels.frontier_expand.ops import frontier_expand_pull
        return frontier_expand_pull(radj, erow, bfs, root, rmatch, level,
                                    block_edges=block_edges,
                                    interpret=interpret)
    target = _proposal_mask(radj, erow, bfs, root, rmatch, level)
    prop = jnp.where(target, radj, IINF)
    row_ix = jnp.where(target, erow, nr)
    return scatter_min(nr, row_ix, prop)


def _winner_compact(cxadj, cadj, bfs, rmatch, nr, isf, *,
                    cap: int, dmax: int):
    """Compact column-gather sweep: O(cap·dmax) instead of O(nnz).

    ``isf`` is the (nc,) frontier mask (WR refinement already applied) the
    caller computed for the eligibility guard — passed in rather than
    recomputed because XLA cannot CSE across the ``lax.cond`` boundary.
    Gathers up to ``cap`` frontier columns and up to ``dmax`` edges each via
    ``cxadj`` offsets.  Only called when the eligibility guard holds
    (frontier fits the capacity), in which case every proposal of the dense
    sweep is present and the min-merge winner is bit-identical.
    """
    nc = bfs.shape[0] - 1
    nnz_pad = cadj.shape[0]
    cols = jnp.nonzero(isf, size=cap, fill_value=nc)[0]         # (cap,)
    starts = cxadj[jnp.minimum(cols, nc)]
    ends = cxadj[jnp.minimum(cols + 1, nc)]                     # fill -> deg 0
    offs = jnp.arange(dmax, dtype=jnp.int32)
    eidx = starts[:, None] + offs[None, :]                      # (cap, dmax)
    valid = offs[None, :] < (ends - starts)[:, None]
    rows = jnp.where(valid, cadj[jnp.clip(eidx, 0, nnz_pad - 1)], nr)
    cm = rmatch[rows]
    col_unvis = bfs[jnp.clip(cm, 0, nc)] == UNVISITED
    target = valid & ((cm >= 0) & col_unvis | (cm == -1))
    prop = jnp.where(target, cols[:, None], IINF)
    rows_ix = jnp.where(target, rows, nr)
    return scatter_min(nr, rows_ix.ravel(), prop.ravel())


def _apply_winner(winner, bfs, root, pred, rmatch, level, *, wr: bool,
                  wr_exact: bool):
    """Fold a per-row winner vector into the BFS state (the paper's Alg. 2
    lines 8-17 / Alg. 4 lines 11-18).  Shared by every sweep direction —
    once the winners agree, everything downstream is identical."""
    nc = bfs.shape[0] - 1
    nr = pred.shape[0] - 1
    upd_r = winner < IINF                                 # (nr+1,) rows reached

    pred = jnp.where(upd_r, winner, pred)
    cm_r = rmatch                                         # row-wise matched col
    visit_r = upd_r & (cm_r >= 0)                         # Alg.2 l.8-12
    end_r = upd_r & (cm_r == -1)                          # Alg.2 l.14-17

    bfs = bfs.at[jnp.where(visit_r, cm_r, nc)].set(level + 1)
    if wr:
        rootvals = root[jnp.clip(winner, 0, nc)]
        root = root.at[jnp.where(visit_r, cm_r, nc)].set(
            jnp.where(visit_r, rootvals, 0))
        # mark the root "satisfied": plain WR writes L0-2, the exact variant
        # encodes the endpoint row as -(r+1) so ALTERNATE can start only the
        # winning endpoint of each tree (paper Sec. 3, last paragraph).
        if wr_exact:
            enc = -(jnp.arange(nr + 1, dtype=jnp.int32) + 1)
        else:
            enc = jnp.full(nr + 1, FOUND, jnp.int32)
        bfs = bfs.at[jnp.where(end_r, rootvals, nc)].min(
            jnp.where(end_r, enc, IINF))
    rmatch = jnp.where(end_r, jnp.int32(-2), rmatch)
    bfs = bfs.at[nc].set(NEG)                             # restore sentinel

    vertex_inserted = jnp.any(visit_r)
    aug_found = jnp.any(end_r)
    return bfs, root, pred, rmatch, vertex_inserted, aug_found


def _expand_level(ecol, cadj, bfs, root, pred, rmatch, level, *, wr: bool,
                  wr_exact: bool, use_pallas: bool, block_edges: int,
                  axis: Optional[str] = None, pallas_fused: bool = True,
                  interpret: Optional[bool] = None, cxadj=None,
                  adaptive: bool = False, compact_cap: int = 0,
                  compact_dmax: int = 0):
    """One level-synchronous frontier expansion. Returns updated state.

    Edge-parallel: every edge (c, r) is one lane.  The per-row conflict
    (several frontier columns reaching the same row) is resolved with a
    deterministic min-merge, standing in for the paper's benign race — fused
    into the Pallas kernel on the default Pallas path, a separate scatter on
    the jnp and legacy paths.

    With ``axis`` set (inside ``shard_map``), ``ecol``/``cadj`` are this
    device's edge shard and the per-row winners of all shards merge with one
    ``lax.pmin`` over the mesh axis — the single collective any
    level-synchronous distributed BFS needs.  Everything after the merge
    operates on replicated O(n) state and is bit-identical on every device.

    ``adaptive`` (requires ``cxadj``, single-device) sizes the frontier each
    level and dispatches the compact column-gather sweep when it fits; the
    compact geometry must be resolved through ``MatcherConfig`` (0 = not
    resolved is an error here — there is no untracked default).
    """
    nc = bfs.shape[0] - 1
    nr = pred.shape[0] - 1
    rt = root if wr else None

    def full(_):
        return _winner_full(ecol, cadj, bfs, rt, rmatch, level, nr,
                            use_pallas=use_pallas, pallas_fused=pallas_fused,
                            block_edges=block_edges, interpret=interpret)

    if adaptive:
        assert cxadj is not None, "adaptive_frontier needs the cxadj offsets"
        assert axis is None, "adaptive_frontier is single-device only"
        assert compact_cap > 0 and compact_dmax > 0, \
            "resolve the compact geometry via MatcherConfig.resolve_cap/" \
            "resolve_dmax (0 means unresolved, not a default)"
        isf = bfs[:-1] == level
        if wr:
            isf &= bfs[jnp.clip(root[:-1], 0, nc)] >= UNVISITED
        deg = cxadj[1:] - cxadj[:-1]
        eligible = ((jnp.sum(isf.astype(jnp.int32)) <= compact_cap)
                    & (jnp.max(jnp.where(isf, deg, 0)) <= compact_dmax))
        winner = jax.lax.cond(
            eligible,
            lambda _: _winner_compact(cxadj, cadj, bfs, rmatch, nr, isf,
                                      cap=compact_cap, dmax=compact_dmax),
            full, None)
    else:
        winner = full(None)

    if axis is not None:                                  # merge edge shards
        winner = jax.lax.pmin(winner, axis)
    return _apply_winner(winner, bfs, root, pred, rmatch, level, wr=wr,
                         wr_exact=wr_exact)


def _expand_level_dirop(ecol, cadj, cxadj, rxadj, radj, erow, bfs, root,
                        pred, rmatch, level, dir_prev, *, wr: bool,
                        wr_exact: bool, use_pallas: bool, block_edges: int,
                        axis: Optional[str], pallas_fused: bool,
                        interpret: Optional[bool], dirop_alpha: float,
                        dirop_beta: float, pull_cap: int, pull_dmax: int):
    """Direction-optimizing frontier expansion (Beamer-style, in-jit).

    Estimates both directions' work from O(n) degree sums — the frontier
    columns' outgoing edges (``fe``, what a push sweep usefully does)
    against the unreached rows' incoming edges (``pe``, what a pull sweep
    must scan) — and ``lax.cond``-dispatches:

    * pull when ``fe * dirop_alpha > pe``;
    * once pulling, keep pulling while ``fe * dirop_beta > pe`` (the
      hysteresis band, ``beta > alpha`` — ``dir_prev`` carries the previous
      level's direction through the BFS loop);
    * the jnp pull is the compact row-gather and additionally requires the
      unreached rows to fit its (cap, dmax) geometry; the Pallas pull and
      the sharded path stream the CSC mirror, no geometry constraint.

    Either branch produces the dense sweep's exact winner vector, so the
    decision is invisible in the matching; with ``axis`` set the usual one
    ``lax.pmin`` merges the per-shard winners, whichever direction each
    level ran (the estimates are computed from replicated state, so every
    shard takes the same branch).  Returns the updated state plus this
    level's direction for the next level's hysteresis.
    """
    nc = bfs.shape[0] - 1
    nr = pred.shape[0] - 1
    rt = root if wr else None

    def full(_):
        return _winner_full(ecol, cadj, bfs, rt, rmatch, level, nr,
                            use_pallas=use_pallas, pallas_fused=pallas_fused,
                            block_edges=block_edges, interpret=interpret)

    isf = bfs[:-1] == level
    if wr:
        isf &= bfs[jnp.clip(root[:-1], 0, nc)] >= UNVISITED
    cdeg = cxadj[1:] - cxadj[:-1]
    fe = jnp.sum(jnp.where(isf, cdeg, 0)).astype(jnp.float32)
    unreached = _unreached_rows(bfs, rmatch)
    rdeg = rxadj[1:] - rxadj[:-1]
    pe = jnp.sum(jnp.where(unreached, rdeg, 0)).astype(jnp.float32)

    use_pull = (fe * dirop_alpha > pe) | (dir_prev & (fe * dirop_beta > pe))
    if axis is None and not use_pallas:
        # compact pull: every unreached row must be gathered in full
        fits = ((jnp.sum(unreached.astype(jnp.int32)) <= pull_cap)
                & (jnp.max(jnp.where(unreached, rdeg, 0)) <= pull_dmax))
        use_pull &= fits
        pull = lambda _: _winner_pull_compact(  # noqa: E731
            rxadj, radj, bfs, rt, rmatch, level, nr, unreached,
            cap=pull_cap, dmax=pull_dmax)
    else:
        pull = lambda _: _winner_pull_stream(   # noqa: E731
            radj, erow, bfs, rt, rmatch, level, nr, use_pallas=use_pallas,
            block_edges=block_edges, interpret=interpret)

    winner = jax.lax.cond(use_pull, pull, full, None)
    if axis is not None:                                  # merge edge shards
        winner = jax.lax.pmin(winner, axis)
    return _apply_winner(winner, bfs, root, pred, rmatch, level, wr=wr,
                         wr_exact=wr_exact) + (use_pull,)


# ---------------------------------------------------------------------------
# ALTERNATE (Alg. 3) + FIXMATCHING
# ---------------------------------------------------------------------------
def _alternate(cmatch, rmatch, pred, start_mask, max_steps):
    """Lock-step speculative alternation of all augmenting paths.

    ``start_mask`` selects the endpoint rows that launch walkers.  Writes of
    concurrent walkers are merged with min-scatters; the paper's line-8
    predecessor check breaks walkers that would chase another path.

    Per step this does ONE ``pred`` gather: the lookup for the next
    position (``pred[matched_row]``) doubles as the line-8 check, and its
    value is carried in the loop state so the old per-step
    ``pred[clip(cur)]`` re-gather is gone.  The two min-scatters only run on
    steps that still have an unbroken walker.  Returns
    ``(cmatch, rmatch, steps)`` — the step count is part of the contract so
    the optimization stays observable (see tests/test_frontier_paths.py).
    """
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1
    rows = jnp.arange(nr + 1, dtype=jnp.int32)
    cur0 = jnp.where(start_mask, rows, jnp.int32(-1))
    pmc0 = pred[jnp.clip(cur0, 0, nr)]                    # pred[cur], hoisted

    def cond(carry):
        cur, _, _, _, steps = carry
        return jnp.any(cur >= 0) & (steps < max_steps)

    def body(carry):
        cur, pmc, cmatch, rmatch, steps = carry
        active = cur >= 0
        curc = jnp.clip(cur, 0, nr)
        mc = pmc                                          # matched_col = pred[cur]
        mcc = jnp.clip(mc, 0, nc)
        mr = cmatch[mcc]                                  # matched_row (snapshot)
        pmr = pred[jnp.clip(mr, 0, nr)]                   # the step's one gather
        # paper line 8: if predecessor[matched_row] == matched_col: break
        brk = active & (mr >= 0) & (pmr == mc)
        act = active & ~brk

        def scatters(ms):
            cm, rm = ms
            # cmatch[mc] <- cur ; rmatch[cur] <- mc  (speculative, min-merged)
            cprop = scatter_min(nc, jnp.where(act, mcc, nc),
                                jnp.where(act, cur, IINF))
            cm = jnp.where(cprop < IINF, cprop, cm)
            rprop = scatter_min(nr, jnp.where(act, curc, nr),
                                jnp.where(act, mc, IINF))
            rm = jnp.where(rprop < IINF, rprop, rm)
            return cm, rm

        # every walker broke this step -> both scatters would be all-sentinel
        cmatch, rmatch = jax.lax.cond(jnp.any(act), scatters,
                                      lambda ms: ms, (cmatch, rmatch))
        cur = jnp.where(act, mr, jnp.int32(-1))
        return cur, pmr, cmatch, rmatch, steps + 1

    _, _, cmatch, rmatch, steps = jax.lax.while_loop(
        cond, body, (cur0, pmc0, cmatch, rmatch, jnp.int32(0)))
    return cmatch, rmatch, steps


def _fix_matching(cmatch, rmatch):
    """Paper's FIXMATCHING, both directions -> a valid matching.

    rmatch[r] <- -1 where cmatch[rmatch[r]] != r, then the symmetric pass on
    columns (needed because deterministic merging can strand a cmatch entry).
    """
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1
    rows = jnp.arange(nr + 1, dtype=jnp.int32)
    cols = jnp.arange(nc + 1, dtype=jnp.int32)
    rmatch = jnp.where(rmatch == -2, jnp.int32(-1), rmatch)
    ok_r = (rmatch >= 0) & (cmatch[jnp.clip(rmatch, 0, nc)] == rows)
    rmatch = jnp.where((rmatch >= 0) & ~ok_r, jnp.int32(-1), rmatch)
    ok_c = (cmatch >= 0) & (rmatch[jnp.clip(cmatch, 0, nr)] == cols)
    cmatch = jnp.where((cmatch >= 0) & ~ok_c, jnp.int32(-1), cmatch)
    return cmatch, rmatch


def _cardinality(cmatch):
    return jnp.sum((cmatch[:-1] >= 0).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Drivers — Algorithm 1 (APsB) and its APFB variant
# ---------------------------------------------------------------------------
def make_solver(cfg: MatcherConfig, axis: Optional[str] = None):
    """Build the pure matcher ``(ecol, cadj, cmatch, rmatch[, cxadj]) ->
    (cmatch, rmatch, phases, fallbacks, certified)``.

    ``certified`` is a device bool: True iff the final phase's BFS proved no
    augmenting path remains (the matching is maximum, Berge).  A run cut
    short by a positive ``cfg.max_phases`` budget returns ``certified=False``
    — the matching is valid but possibly sub-maximum; with
    ``cfg.degrade_maximal`` it is additionally made maximal by one greedy
    augmentation round (single-device path; :class:`~repro.matching.sharded.
    ShardedMatcher` applies the same round outside the ``shard_map`` region).

    Shape-polymorphic: ``nc``/``nr``/``block_edges`` are derived from the
    argument shapes at trace time, so one returned function serves every size
    bucket and closes under ``jit`` and ``vmap``.

    ``axis`` names a mesh axis for the distributed variant: the returned
    function then expects to run *inside* ``shard_map`` with ``ecol``/``cadj``
    edge-sharded over that axis and the O(n) state replicated.  The only
    communication is one ``pmin`` per BFS level in :func:`_expand_level` —
    on the fused Pallas path each shard's kernel already emits its local
    per-row winner vector, so the pmin is the whole merge.  ALTERNATE and
    FIXMATCHING run redundantly-but-identically on the replicated state
    (their cost is O(n) per phase vs O(nnz/D) for expansion, so sharding
    them would buy nothing).

    ``cfg.adaptive_frontier`` additionally needs the ``cxadj`` offsets
    (pass ``match_fn(..., cxadj=graph.cxadj)``) and is single-device only.
    ``cfg.dirop`` needs ``cxadj`` plus the CSC mirror arrays
    (``rxadj``/``radj``/``erow`` of ``DeviceCSR.with_csc``); it composes
    with ``axis`` — each shard pulls over its own CSC slice and the same
    single ``pmin`` merges the winners.
    """
    wr = cfg.kernel == "gpubfs_wr"
    if cfg.adaptive_frontier and axis is not None:
        raise ValueError(
            "adaptive_frontier composes with the dense per-shard sweep only; "
            "disable it for ShardedMatcher (axis=%r); dirop is the "
            "direction heuristic that does compose with sharding" % (axis,))

    def match_fn(ecol, cadj, cmatch, rmatch, cxadj=None, rxadj=None,
                 radj=None, erow=None):
        if cfg.adaptive_frontier and cxadj is None:
            raise ValueError(
                "adaptive_frontier needs the cxadj column offsets; call the "
                "solver with cxadj= (Matcher.solve passes graph.cxadj)")
        if cfg.dirop and (cxadj is None or rxadj is None or radj is None
                          or erow is None):
            raise ValueError(
                "dirop needs cxadj plus the CSC mirror (rxadj/radj/erow); "
                "build it with DeviceCSR.with_csc() — Matcher.solve passes "
                "it through when present")
        nc = cmatch.shape[0] - 1
        nr = rmatch.shape[0] - 1
        block_edges = cfg.pallas_block_edges or default_block_edges(
            int(ecol.shape[0]), cfg.schedule)
        # compact/pull geometry: the ONE auto rule lives on MatcherConfig
        # (pure in (config, bucket), so the 0 marker in cache keys is safe)
        compact_cap = cfg.resolve_cap(cfg.compact_cap, nc)
        compact_dmax = cfg.resolve_dmax(cfg.compact_dmax)
        pull_cap = cfg.resolve_cap(cfg.pull_cap, nr)
        pull_dmax = cfg.resolve_dmax(cfg.pull_dmax)

        def phase_bfs(cmatch, rmatch):
            """Inner while of Alg. 1: level-synchronous BFS to exhaustion/first hit."""
            bfs, root = level0_state(cmatch)
            pred = jnp.full(nr + 1, jnp.int32(nc), jnp.int32)   # fresh each phase

            def cond(c):
                _, _, _, _, level, ins, aug, aug_lvl, _ = c
                go = ins
                if cfg.algo == "apsb":
                    go = go & ~aug                               # Alg.1 l.9-10 break
                elif cfg.tail_levels > 0:
                    # bounded tail: expand at most tail_levels past the first
                    # augmenting level (beyond-paper, see MatcherConfig)
                    go = go & (level <= aug_lvl + cfg.tail_levels)
                return go

            def body(c):
                bfs, root, pred, rmatch, level, _, aug, aug_lvl, dirp = c
                if cfg.dirop:
                    bfs, root, pred, rmatch, ins, aug_l, dirp = \
                        _expand_level_dirop(
                            ecol, cadj, cxadj, rxadj, radj, erow, bfs, root,
                            pred, rmatch, level, dirp, wr=wr,
                            wr_exact=cfg.wr_exact, use_pallas=cfg.use_pallas,
                            block_edges=block_edges, axis=axis,
                            pallas_fused=cfg.pallas_fused,
                            interpret=cfg.pallas_interpret,
                            dirop_alpha=cfg.dirop_alpha,
                            dirop_beta=cfg.dirop_beta,
                            pull_cap=pull_cap, pull_dmax=pull_dmax)
                else:
                    bfs, root, pred, rmatch, ins, aug_l = _expand_level(
                        ecol, cadj, bfs, root, pred, rmatch, level, wr=wr,
                        wr_exact=cfg.wr_exact, use_pallas=cfg.use_pallas,
                        block_edges=block_edges, axis=axis,
                        pallas_fused=cfg.pallas_fused,
                        interpret=cfg.pallas_interpret, cxadj=cxadj,
                        adaptive=cfg.adaptive_frontier,
                        compact_cap=compact_cap,
                        compact_dmax=compact_dmax)
                aug_lvl = jnp.where(aug_l & (aug_lvl == IINF), level, aug_lvl)
                return (bfs, root, pred, rmatch, level + 1, ins, aug | aug_l,
                        aug_lvl, dirp)

            bfs, root, pred, rmatch, _, _, aug, _, _ = jax.lax.while_loop(
                cond, body, (bfs, root, pred, rmatch, L0, jnp.bool_(True),
                             jnp.bool_(False), IINF, jnp.bool_(False)))
            return bfs, root, pred, rmatch, aug

        def start_mask_fn(bfs, root, rmatch):
            mask = rmatch == -2
            if cfg.wr_exact:
                # only the winning endpoint of each satisfied tree starts a walker
                enc = bfs[:-1]                                   # (nc,)
                is_win = enc <= -1
                endpoint = jnp.where(is_win, -(enc + 1), nr)
                wins = jnp.zeros(nr + 1, bool).at[endpoint].set(True)
                wins = wins.at[nr].set(False)
                mask = mask & wins
            return mask

        max_steps = jnp.int32(2 * (min(nc, nr) + 2))

        def outer_body(carry):
            cmatch, rmatch, _, phases, fallbacks = carry
            cm0, rm0 = cmatch, rmatch                            # phase snapshot
            card0 = _cardinality(cm0)
            bfs, root, pred, rmatch_b, aug = phase_bfs(cmatch, rmatch)

            def do_phase(_):
                mask = start_mask_fn(bfs, root, rmatch_b)
                cm1, rm1, _ = _alternate(cm0,
                                         jnp.where(mask, jnp.int32(-2), rm0),
                                         pred, mask, max_steps)
                cm1, rm1 = _fix_matching(cm1, rm1)

                def fallback(_):
                    # guard: speculative phase gained nothing -> augment exactly one
                    # shortest path on the snapshot (single walker cannot conflict).
                    any_ep = rmatch_b == -2
                    first = jnp.argmax(any_ep)                   # lowest endpoint row
                    one = jnp.zeros(nr + 1, bool).at[first].set(jnp.any(any_ep))
                    cm2, rm2, _ = _alternate(cm0, rm0, pred, one, max_steps)
                    return _fix_matching(cm2, rm2) + (jnp.int32(1),)

                cm1, rm1, fb = jax.lax.cond(
                    _cardinality(cm1) > card0,
                    lambda _: (cm1, rm1, jnp.int32(0)), fallback, None)
                return cm1, rm1, fb

            cmatch, rmatch, fb = jax.lax.cond(
                aug, do_phase, lambda _: (cm0, rm0, jnp.int32(0)), None)
            return cmatch, rmatch, aug, phases + 1, fallbacks + fb

        def outer_cond(carry):
            *_, aug, phases, _ = carry
            limit = cfg.max_phases if cfg.max_phases > 0 else nc + 2
            return aug & (phases < limit)

        carry = (cmatch, rmatch, jnp.bool_(True), jnp.int32(0), jnp.int32(0))
        carry = jax.lax.while_loop(outer_cond, outer_body, carry)
        cmatch, rmatch, aug, phases, fallbacks = carry
        # aug is the last BFS verdict: False means the phase found no
        # augmenting path — Berge certifies the matching maximum.  A
        # budget-truncated exit leaves aug True: valid but uncertified.
        certified = ~aug
        if cfg.degrade_maximal and cfg.max_phases > 0 and axis is None:
            # Budget exhausted -> the truncated matching may leave free
            # columns adjacent to free rows.  One speculative greedy round
            # (the `cheap` warm start's augment-only pass) restores
            # maximality without another BFS phase.  Local import:
            # warmstart.py imports solver internals from this module.
            from .warmstart import cheap_init
            cmatch, rmatch = jax.lax.cond(
                certified, lambda cr: cr,
                lambda cr: cheap_init(ecol, cadj, *cr), (cmatch, rmatch))
        return cmatch, rmatch, phases, fallbacks, certified

    return match_fn
