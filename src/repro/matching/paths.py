"""Solve-path registry: every end-to-end way this package computes a matching.

The same APFB/APsB solve loop reaches the device through several execution
paths — plain jnp, the legacy proposal kernel + ``scatter_min`` merge, the
fused Pallas kernel, the compact adaptive-frontier gather, the
direction-optimizing engine (jnp and Pallas pull sweeps), and the
edge-sharded ``shard_map`` program.  All must produce a maximum matching on
every instance; several must be *bit-identical*.  This registry gives that
family one enumerable surface so the differential fuzz harness
(:mod:`repro.corpus.verify`), the parity tests and the benchmarks stop
hand-rolling their own config lists that drift apart.

Each :class:`SolvePath` is a named set of :class:`MatcherConfig` overrides
plus how to build its matcher; :meth:`SolvePath.run_host` is the
host-graph-in, host-matching-out closure the harness calls.  Tests can
:func:`register_solve_path` throwaway paths (e.g. a deliberately broken
runner to exercise the mismatch artifact machinery) and must unregister
them again.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.core.csr import BipartiteCSR

from .api import Matcher
from .config import MatcherConfig
from .device_csr import DeviceCSR
from .sharded import ShardedMatcher


@dataclasses.dataclass(frozen=True)
class SolvePath:
    """One registered end-to-end solve configuration.

    ``overrides`` are :func:`dataclasses.replace` fields applied on top of a
    caller's base :class:`MatcherConfig` (so a path composes with any paper
    variant); ``sharded`` selects :class:`ShardedMatcher` over the mesh;
    ``runner``, when set, replaces the standard device round-trip entirely —
    a test hook for injecting broken paths into the fuzz harness.
    """
    name: str
    overrides: Mapping[str, object]
    sharded: bool = False
    runner: Optional[Callable] = None

    def configure(self, base: MatcherConfig = MatcherConfig()
                  ) -> MatcherConfig:
        return dataclasses.replace(base, **dict(self.overrides))

    def matcher(self, base: MatcherConfig = MatcherConfig(),
                warm_start: str = "cheap", mesh=None) -> Matcher:
        cfg = self.configure(base)
        if self.sharded:
            import jax
            if mesh is None:
                mesh = jax.make_mesh((jax.device_count(),), ("data",))
            return ShardedMatcher(mesh, "data", cfg, warm_start)
        return Matcher(cfg, warm_start)

    def run_host(self, g: BipartiteCSR,
                 base: MatcherConfig = MatcherConfig(),
                 warm_start: str = "cheap", mesh=None,
                 pad: Optional[Tuple[int, int, int]] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Host graph in, host ``(cmatch, rmatch)`` out (padding stripped).

        ``pad=(nc, nr, nnz_cap)`` places the instance in a declared size
        bucket so many instances share one compiled program — the fuzz
        harness's compile budget depends on it.  Padded vertices are
        isolated, so the returned true-size matching is unaffected.
        """
        if self.runner is not None:
            return self.runner(g, base=base, warm_start=warm_start)
        graph = DeviceCSR.from_host(g)
        if pad is not None:
            nc, nr, cap = pad
            graph = graph.pad_vertices(nc, nr).pad_to(cap)
        if self.configure(base).dirop:
            graph = graph.with_csc()       # sharded dirop: mirror pre-shard
        state = self.matcher(base, warm_start, mesh).run(graph)
        cm, rm = state.to_host()
        return cm[: g.nc], rm[: g.nr]


SOLVE_PATHS: Dict[str, SolvePath] = {}


def register_solve_path(name: str, overrides: Optional[Mapping] = None, *,
                        sharded: bool = False,
                        runner: Optional[Callable] = None) -> SolvePath:
    path = SolvePath(name, dict(overrides or {}), sharded, runner)
    SOLVE_PATHS[name] = path
    return path


def unregister_solve_path(name: str) -> None:
    SOLVE_PATHS.pop(name, None)


def solve_path_names() -> Tuple[str, ...]:
    return tuple(SOLVE_PATHS)


# the built-in paths — one per frontier-sweep execution strategy.  Geometry
# knobs (compact_cap / pull_cap / block_edges) stay on auto: their resolution
# is part of what the differential harness must cover.
register_solve_path("jnp", {})
register_solve_path("legacy", dict(use_pallas=True, pallas_fused=False))
register_solve_path("fused", dict(use_pallas=True, pallas_fused=True))
register_solve_path("adaptive", dict(adaptive_frontier=True))
register_solve_path("dirop", dict(dirop=True))
register_solve_path("dirop_pallas", dict(dirop=True, use_pallas=True))
register_solve_path("sharded", {}, sharded=True)
