"""Matcher variant configuration (the paper's eight-variant matrix)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    """One of the paper's eight variants (2 algos x 2 BFS kernels x 2
    schedules), plus the frontier-sweep execution knobs.

    The sweep knobs (``use_pallas`` .. ``pull_dmax``) select *how* the
    per-level frontier expansion runs; they never change the matching
    the solver returns — every path is bit-identical to the deterministic
    min-merge semantics (asserted in tests/test_frontier_paths.py).  All of
    them are fields of this frozen dataclass, so every one lands in the
    compile-cache key by construction — there are no untracked execution
    knobs hiding in kwarg defaults.
    """

    algo: str = "apfb"          # "apfb" (HKDW-like) | "apsb" (HK-like)
    kernel: str = "gpubfs_wr"   # "gpubfs" | "gpubfs_wr"
    schedule: str = "ct"        # "ct" | "mt" — edge-tile geometry (Pallas path)
    wr_exact: bool = False      # the APsB-GPUBFS-WR refinement (negative-row encoding)
    use_pallas: bool = False    # route frontier expansion through the Pallas kernel
    max_phases: int = 0         # 0 = until maximum (bounded internally)
    # When a positive max_phases budget exhausts before the solver certifies
    # the matching maximum, run one extra greedy augmentation round
    # (the `cheap` warm start's speculative pass, Birn-et-al maximal
    # matching) over the truncated result so the degraded answer is at
    # least MAXIMAL — no free column shares an edge with a free row.  The
    # serving degradation ladder turns this on for deadline-bounded solves;
    # it stays off by default because the corpus heuristic replay
    # (corpus/heuristic.py) steps the solver with max_phases=1 and its
    # CI-gated trajectories must not change under it.
    degrade_maximal: bool = False
    # beyond-paper: bound the BFS tail after the first augmenting level.
    # 0 = paper-faithful (APsB stops immediately, APFB exhausts the
    # frontier); k>0 on APFB = expand at most k more levels — interpolates
    # between the paper's two drivers (benchmarks/perf_matcher.py).
    tail_levels: int = 0
    # -- Pallas frontier-sweep geometry -------------------------------------
    # fused kernel (in-VMEM per-row winner merge, no (nnz,) proposal array);
    # False = legacy two-step path (proposal kernel + XLA scatter), kept for
    # benchmarking the fusion win (benchmarks/perf_smoke.py).
    pallas_fused: bool = True
    # None = auto: compile for real on accelerator backends, interpret only
    # on CPU.  Resolved once per Matcher (``canonical()``) so the concrete
    # bool — not the auto marker — lands in the compile-cache key.
    pallas_interpret: Optional[bool] = None
    # 0 = auto (default_block_edges: CT 4096 / MT 512, clamped to the padded
    # edge count); >0 = explicit tile size, e.g. from benchmarks/autotune.py.
    pallas_block_edges: int = 0
    # -- beyond-paper: frontier-adaptive dispatch (default off) -------------
    # Track the frontier size each level and switch to a compact
    # column-gather sweep (O(cap * dmax) instead of O(nnz)) whenever the
    # frontier fits `compact_cap` columns of degree <= `compact_dmax`;
    # falls back to the full sweep at runtime otherwise, so results stay
    # bit-identical.  0 = auto-size to the bucket (resolve_cap/resolve_dmax
    # below — the ONE definition of the auto geometry; solve.make_solver
    # resolves per bucket, a pure function of (config, bucket) so the 0
    # marker in the compile-cache key is unambiguous).  Single-device only
    # (the sharded path keeps the dense per-shard sweep + one pmin).
    adaptive_frontier: bool = False
    compact_cap: int = 0
    compact_dmax: int = 0
    # -- beyond-paper: direction-optimizing frontier engine (default off) ---
    # Beamer-style push/pull switching per BFS level, in-jit: estimate the
    # frontier's outgoing edges (push work actually useful) against the
    # unreached rows' incoming edges (pull work) and `lax.cond`-dispatch a
    # pull sweep over the CSC mirror (`DeviceCSR.with_csc`) when
    #     frontier_edges * dirop_alpha > pull_edges,
    # staying in pull — hysteresis — while
    #     frontier_edges * dirop_beta  > pull_edges   (beta > alpha).
    # On the jnp path the pull sweep is a compact row-gather of
    # O(pull_cap * pull_dmax) (0 = auto, same resolution rule as the
    # compact push geometry but sized on nr) and additionally requires the
    # unreached rows to fit that geometry; on the Pallas path it is the
    # streaming `frontier_expand_pull` kernel (row-sorted tiles whose merge
    # skips when the tile proposes nothing).  Either way the winners are
    # bit-identical to the push sweeps, so the dispatch never changes the
    # matching.  Composes with ShardedMatcher (per-shard pull over the CSC
    # shard, the one per-level pmin unchanged).  Mutually exclusive with
    # `adaptive_frontier`, which it generalizes.
    # The alpha/beta defaults come from the committed corpus sweep
    # (BENCH_PR7.json, ``corpus.alpha_sweep`` / ``_summary`` rows, tiny
    # scale; regenerate via benchmarks/run.py --update-baseline): 8/32 ties
    # the best geomean across the 10-family corpus (0.997 vs push-only) and
    # is the clear winner on the long-diameter families (grid 0.699) where
    # pull tile-skipping pays; RCP permutation erases most of that win
    # (grid_rcp 0.951), which is the paper's locality story.  The per-family
    # rows are gated in CI (``corpus.heuristic``), so changing these
    # defaults without refreshing the baseline fails the bench gate.
    dirop: bool = False
    dirop_alpha: float = 8.0
    dirop_beta: float = 32.0
    pull_cap: int = 0
    pull_dmax: int = 0

    def __post_init__(self):
        assert self.algo in ("apfb", "apsb")
        assert self.kernel in ("gpubfs", "gpubfs_wr")
        assert self.schedule in ("ct", "mt")
        if self.wr_exact:
            assert self.kernel == "gpubfs_wr"
        assert self.pallas_block_edges >= 0, self.pallas_block_edges
        assert self.compact_cap >= 0 and self.compact_dmax >= 0, \
            (self.compact_cap, self.compact_dmax)
        assert self.pull_cap >= 0 and self.pull_dmax >= 0, \
            (self.pull_cap, self.pull_dmax)
        assert self.dirop_alpha > 0 and self.dirop_beta >= self.dirop_alpha, \
            ("hysteresis needs 0 < dirop_alpha <= dirop_beta",
             self.dirop_alpha, self.dirop_beta)
        if self.dirop and self.adaptive_frontier:
            raise ValueError(
                "dirop generalizes adaptive_frontier; enable one, not both")

    @staticmethod
    def resolve_cap(auto_or_value: int, n: int) -> int:
        """The 0 = auto capacity rule for the compact sweeps: n/8 clamped to
        [64, 1024] (well under any dense O(nnz) sweep).  ``n`` is nc for the
        push-compact gather, nr for the pull gather."""
        return auto_or_value or max(64, min(1024, n // 8))

    @staticmethod
    def resolve_dmax(auto_or_value: int) -> int:
        """The 0 = auto per-vertex degree bound of the compact sweeps."""
        return auto_or_value or 8

    @property
    def name(self) -> str:
        s = f"{self.algo}-{self.kernel}-{self.schedule}"
        return s + ("-exact" if self.wr_exact else "")

    def canonical(self) -> "MatcherConfig":
        """Resolve the ``pallas_interpret=None`` auto marker to a concrete
        bool (interpret only on CPU) so compile-cache keys built from this
        config always carry the real compilation mode."""
        if self.pallas_interpret is not None:
            return self
        from repro.kernels.frontier_expand import resolve_interpret
        return dataclasses.replace(self,
                                   pallas_interpret=resolve_interpret(None))


VARIANTS = tuple(
    MatcherConfig(algo=a, kernel=k, schedule=s,
                  wr_exact=(a == "apsb" and k == "gpubfs_wr"))
    for a in ("apfb", "apsb")
    for k in ("gpubfs", "gpubfs_wr")
    for s in ("ct", "mt")
)
