"""Matcher variant configuration (the paper's eight-variant matrix)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    """One of the paper's eight variants (2 algos x 2 BFS kernels x 2
    schedules), plus the frontier-sweep execution knobs.

    The sweep knobs (``use_pallas`` .. ``compact_dmax``) select *how* the
    O(nnz) per-level frontier expansion runs; they never change the matching
    the solver returns — every path is bit-identical to the deterministic
    min-merge semantics (asserted in tests/test_frontier_paths.py).
    """

    algo: str = "apfb"          # "apfb" (HKDW-like) | "apsb" (HK-like)
    kernel: str = "gpubfs_wr"   # "gpubfs" | "gpubfs_wr"
    schedule: str = "ct"        # "ct" | "mt" — edge-tile geometry (Pallas path)
    wr_exact: bool = False      # the APsB-GPUBFS-WR refinement (negative-row encoding)
    use_pallas: bool = False    # route frontier expansion through the Pallas kernel
    max_phases: int = 0         # 0 = until maximum (bounded internally)
    # beyond-paper: bound the BFS tail after the first augmenting level.
    # 0 = paper-faithful (APsB stops immediately, APFB exhausts the
    # frontier); k>0 on APFB = expand at most k more levels — interpolates
    # between the paper's two drivers (benchmarks/perf_matcher.py).
    tail_levels: int = 0
    # -- Pallas frontier-sweep geometry -------------------------------------
    # fused kernel (in-VMEM per-row winner merge, no (nnz,) proposal array);
    # False = legacy two-step path (proposal kernel + XLA scatter), kept for
    # benchmarking the fusion win (benchmarks/perf_smoke.py).
    pallas_fused: bool = True
    # None = auto: compile for real on accelerator backends, interpret only
    # on CPU.  Resolved once per Matcher (``canonical()``) so the concrete
    # bool — not the auto marker — lands in the compile-cache key.
    pallas_interpret: Optional[bool] = None
    # 0 = auto (default_block_edges: CT 4096 / MT 512, clamped to the padded
    # edge count); >0 = explicit tile size, e.g. from benchmarks/autotune.py.
    pallas_block_edges: int = 0
    # -- beyond-paper: frontier-adaptive dispatch (default off) -------------
    # Track the frontier size each level and switch to a compact
    # column-gather sweep (O(cap * dmax) instead of O(nnz)) whenever the
    # frontier fits `compact_cap` columns of degree <= `compact_dmax`;
    # falls back to the full sweep at runtime otherwise, so results stay
    # bit-identical.  0 = auto-size to the bucket (cap = nc/8 clamped to
    # [64, 1024], dmax = 8) so the compact sweep stays well under the dense
    # O(nnz) cost.  Single-device only (the sharded path keeps the dense
    # per-shard sweep + one pmin).
    adaptive_frontier: bool = False
    compact_cap: int = 0
    compact_dmax: int = 0

    def __post_init__(self):
        assert self.algo in ("apfb", "apsb")
        assert self.kernel in ("gpubfs", "gpubfs_wr")
        assert self.schedule in ("ct", "mt")
        if self.wr_exact:
            assert self.kernel == "gpubfs_wr"
        assert self.pallas_block_edges >= 0, self.pallas_block_edges
        assert self.compact_cap >= 0 and self.compact_dmax >= 0, \
            (self.compact_cap, self.compact_dmax)

    @property
    def name(self) -> str:
        s = f"{self.algo}-{self.kernel}-{self.schedule}"
        return s + ("-exact" if self.wr_exact else "")

    def canonical(self) -> "MatcherConfig":
        """Resolve the ``pallas_interpret=None`` auto marker to a concrete
        bool (interpret only on CPU) so compile-cache keys built from this
        config always carry the real compilation mode."""
        if self.pallas_interpret is not None:
            return self
        from repro.kernels.frontier_expand import resolve_interpret
        return dataclasses.replace(self,
                                   pallas_interpret=resolve_interpret(None))


VARIANTS = tuple(
    MatcherConfig(algo=a, kernel=k, schedule=s,
                  wr_exact=(a == "apsb" and k == "gpubfs_wr"))
    for a in ("apfb", "apsb")
    for k in ("gpubfs", "gpubfs_wr")
    for s in ("ct", "mt")
)
