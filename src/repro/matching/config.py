"""Matcher variant configuration (the paper's eight-variant matrix)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    """One of the paper's eight variants (2 algos x 2 BFS kernels x 2 schedules)."""

    algo: str = "apfb"          # "apfb" (HKDW-like) | "apsb" (HK-like)
    kernel: str = "gpubfs_wr"   # "gpubfs" | "gpubfs_wr"
    schedule: str = "ct"        # "ct" | "mt" — edge-tile geometry (Pallas path)
    wr_exact: bool = False      # the APsB-GPUBFS-WR refinement (negative-row encoding)
    use_pallas: bool = False    # route frontier expansion through the Pallas kernel
    max_phases: int = 0         # 0 = until maximum (bounded internally)
    # beyond-paper: bound the BFS tail after the first augmenting level.
    # 0 = paper-faithful (APsB stops immediately, APFB exhausts the
    # frontier); k>0 on APFB = expand at most k more levels — interpolates
    # between the paper's two drivers (benchmarks/perf_matcher.py).
    tail_levels: int = 0

    def __post_init__(self):
        assert self.algo in ("apfb", "apsb")
        assert self.kernel in ("gpubfs", "gpubfs_wr")
        assert self.schedule in ("ct", "mt")
        if self.wr_exact:
            assert self.kernel == "gpubfs_wr"

    @property
    def name(self) -> str:
        s = f"{self.algo}-{self.kernel}-{self.schedule}"
        return s + ("-exact" if self.wr_exact else "")


VARIANTS = tuple(
    MatcherConfig(algo=a, kernel=k, schedule=s,
                  wr_exact=(a == "apsb" and k == "gpubfs_wr"))
    for a in ("apfb", "apsb")
    for k in ("gpubfs", "gpubfs_wr")
    for s in ("ct", "mt")
)
