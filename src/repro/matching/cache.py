"""Explicit compile cache for matcher programs.

One process-wide table keyed on ``(bucket shape, MatcherConfig, warm start,
entry point)`` replaces the ``functools.lru_cache``-wrapped jits that used to
be scattered across ``core/matcher.py`` and ``core/cheap.py``.  Centralizing
it makes compilation observable (:func:`compile_cache_info`), evictable
(:func:`compile_cache_clear`) and keyed on exactly the things that force a
recompile: the padded bucket shape and the variant configuration.

The table is guarded by a reentrant lock: the serving layer
(``repro.serving``) hits it concurrently from its flush thread, AOT warmup,
and whatever thread calls ``submit``.  Capacity is ``MAX_ENTRIES``,
overridable with :func:`set_max_entries`; evictions are counted and exposed
in :func:`compile_cache_info` so a serving deployment can see when its
declared warmup grid no longer fits the cache.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Hashable, Tuple

import jax

MAX_ENTRIES = 256   # parity with the lru_cache maxsize this table replaced

_CACHE: Dict[Hashable, Callable] = {}
_HITS = 0
_MISSES = 0
_EVICTIONS = 0
_LOCK = threading.RLock()
_TLS = threading.local()      # per-thread hit/miss tallies (see below)


def _thread_counts() -> dict:
    counts = getattr(_TLS, "counts", None)
    if counts is None:
        counts = _TLS.counts = {"hits": 0, "misses": 0}
    return counts


def set_max_entries(n: int) -> int:
    """Override the cache capacity; returns the previous value.

    Shrinking below the current population evicts LRU entries immediately
    (counted as evictions).  A serving deployment sizes this to its warmup
    grid so warmed programs are never evicted by stray compiles.
    """
    global MAX_ENTRIES, _EVICTIONS
    assert n >= 1, f"cache capacity must be positive, got {n}"
    with _LOCK:
        old, MAX_ENTRIES = MAX_ENTRIES, int(n)
        while len(_CACHE) > MAX_ENTRIES:
            del _CACHE[next(iter(_CACHE))]
            _EVICTIONS += 1
    return old


def compile_cache_key(bucket_key: Tuple[int, ...], cfg, warm_start: str,
                      entry: str) -> Hashable:
    """Canonical key: (bucket shape, config, warm start, entry point).

    ``cfg`` must be the *canonical* MatcherConfig (``MatcherConfig.
    canonical()`` — ``Matcher.__init__`` applies it): the Pallas
    ``pallas_interpret=None`` auto marker is resolved to the backend's
    concrete compilation mode first, so a program compiled in interpret mode
    can never be served where a compiled kernel was requested (and the other
    way around), and every execution-path knob (``use_pallas``,
    ``pallas_fused``, ``pallas_block_edges``, ``adaptive_frontier``,
    ``dirop`` + its heuristic/geometry fields, ...) lands in the key by
    being part of the frozen dataclass.  ``bucket_key`` additionally carries
    the CSC-mirror marker (``DeviceCSR.bucket_key`` appends ``"csc"``), so a
    mirrored graph — different pytree leaves, different traced program —
    never shares an entry with a bare one.
    """
    return (bucket_key, cfg, warm_start, entry)


def get_compiled(key: Hashable, build: Callable[[], Callable],
                 static_argnums=()) -> Callable:
    """Jitted program for ``key``, building (and jitting) it on first use."""
    global _HITS, _MISSES, _EVICTIONS
    counts = _thread_counts()
    with _LOCK:
        fn = _CACHE.get(key)
        if fn is None:
            _MISSES += 1
            counts["misses"] += 1
            fn = jax.jit(build(), static_argnums=static_argnums)
            while len(_CACHE) >= MAX_ENTRIES:        # LRU eviction
                del _CACHE[next(iter(_CACHE))]
                _EVICTIONS += 1
            _CACHE[key] = fn
        else:
            _HITS += 1
            counts["hits"] += 1
            _CACHE[key] = _CACHE.pop(key)            # move to MRU position
    return fn


def compile_cache_thread_info() -> dict:
    """Hits/misses made by the *calling thread* (since it first touched the
    cache).  The serving dispatcher reads deltas of this around each flush so
    concurrent compiles on other threads (warmup, direct Matcher users) are
    never misattributed to a dispatch."""
    return dict(_thread_counts())


def compile_cache_info() -> dict:
    with _LOCK:
        return {"entries": len(_CACHE), "hits": _HITS, "misses": _MISSES,
                "evictions": _EVICTIONS, "max_entries": MAX_ENTRIES,
                "keys": tuple(_CACHE)}


def compile_cache_clear() -> None:
    global _HITS, _MISSES, _EVICTIONS
    with _LOCK:
        _CACHE.clear()
        _HITS = 0
        _MISSES = 0
        _EVICTIONS = 0
