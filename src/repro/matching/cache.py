"""Explicit compile cache for matcher programs.

One process-wide table keyed on ``(bucket shape, MatcherConfig, warm start,
entry point)`` replaces the ``functools.lru_cache``-wrapped jits that used to
be scattered across ``core/matcher.py`` and ``core/cheap.py``.  Centralizing
it makes compilation observable (:func:`compile_cache_info`), evictable
(:func:`compile_cache_clear`) and keyed on exactly the things that force a
recompile: the padded bucket shape and the variant configuration.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, Tuple

import jax

MAX_ENTRIES = 256   # parity with the lru_cache maxsize this table replaced

_CACHE: Dict[Hashable, Callable] = {}
_HITS = 0
_MISSES = 0


def compile_cache_key(bucket_key: Tuple[int, ...], cfg, warm_start: str,
                      entry: str) -> Hashable:
    """Canonical key: (bucket shape, config, warm start, entry point)."""
    return (bucket_key, cfg, warm_start, entry)


def get_compiled(key: Hashable, build: Callable[[], Callable],
                 static_argnums=()) -> Callable:
    """Jitted program for ``key``, building (and jitting) it on first use."""
    global _HITS, _MISSES
    fn = _CACHE.get(key)
    if fn is None:
        _MISSES += 1
        fn = jax.jit(build(), static_argnums=static_argnums)
        while len(_CACHE) >= MAX_ENTRIES:        # LRU eviction
            del _CACHE[next(iter(_CACHE))]
        _CACHE[key] = fn
    else:
        _HITS += 1
        _CACHE[key] = _CACHE.pop(key)            # move to MRU position
    return fn


def compile_cache_info() -> dict:
    return {"entries": len(_CACHE), "hits": _HITS, "misses": _MISSES,
            "keys": tuple(_CACHE)}


def compile_cache_clear() -> None:
    global _HITS, _MISSES
    _CACHE.clear()
    _HITS = 0
    _MISSES = 0
