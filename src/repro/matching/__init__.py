"""Device-resident matching API for the paper's GPU algorithms.

The public surface of the reproduction:

* :class:`DeviceCSR` — pytree bipartite graph (column-major CSR + the
  edge-parallel view) that passes straight through ``jax.jit`` / ``jax.vmap``;
* :class:`MatcherConfig` — one of the paper's eight variants;
* :class:`Matcher` — facade whose :meth:`Matcher.run` composes a registered
  warm start (``"none" | "cheap" | "karp_sipser"``) with the APFB/APsB solver
  in ONE compiled program (no host hop between init and solve);
* :class:`MatchState` / :class:`MatchStats` — pytree results that stay on
  device until the caller asks;
* :func:`match_many` — vmap-batched matching over a stacked ``DeviceCSR``
  bucket (many concurrent matching requests, one dispatch);
* :class:`ShardedMatcher` / :func:`match_sharded` — the same solve loop with
  edges partitioned over a device mesh (:meth:`DeviceCSR.shard`), one
  ``pmin`` collective per BFS level (the paper's stated future work);
* an explicit compile cache keyed on (bucket shape, config, warm start, and
  for the sharded path mesh/axis), replacing the scattered per-module
  ``functools.lru_cache`` jits.

``repro.core.maximum_matching`` / ``cheap_matching_jax`` /
``repro.core.distributed`` remain as thin numpy-compat wrappers over this
package.  ``docs/architecture.md`` documents the design; ``docs/paper_map.md``
maps every paper algorithm to its implementation here.
"""
from .config import MatcherConfig, VARIANTS
from .device_csr import DeviceCSR, GraphValidationError, validate_structure
from .state import MatchState, MatchStats
from .warmstart import WARM_STARTS, register_warm_start, warm_start_names
from .api import Matcher, match_many, maximum_matching_device
from .sharded import ShardedMatcher, match_sharded, mesh_cache_key
from .paths import (SOLVE_PATHS, SolvePath, register_solve_path,
                    solve_path_names, unregister_solve_path)
from .cache import (compile_cache_clear, compile_cache_info,
                    compile_cache_key, get_compiled)

__all__ = [
    "MatcherConfig", "VARIANTS",
    "DeviceCSR", "GraphValidationError", "validate_structure",
    "MatchState", "MatchStats",
    "Matcher", "match_many", "maximum_matching_device",
    "ShardedMatcher", "match_sharded", "mesh_cache_key",
    "SOLVE_PATHS", "SolvePath", "register_solve_path",
    "solve_path_names", "unregister_solve_path",
    "WARM_STARTS", "register_warm_start", "warm_start_names",
    "compile_cache_clear", "compile_cache_info", "compile_cache_key",
    "get_compiled",
]
