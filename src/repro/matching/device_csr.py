"""Device-resident bipartite CSR graph as a registered JAX pytree.

``DeviceCSR`` mirrors :class:`repro.core.csr.BipartiteCSR` but its arrays are
``jax.Array`` leaves, so a graph passes straight through ``jax.jit`` /
``jax.vmap`` boundaries with no host transfer.  The true sizes ``nc``/``nr``
are static pytree metadata (they define the array shapes and therefore the
compiled program); the true edge count ``nnz`` stays a device scalar leaf so a
stacked batch of graphs may differ in it (padding edges carry sentinel
endpoints and are inert in every kernel).

Size-bucket helpers (:meth:`DeviceCSR.pad_to`, :func:`bucket_nnz`) round the
edge capacity up to a small set of shapes so the compile cache stays bounded,
and :meth:`DeviceCSR.stack` builds the batched bucket consumed by
:func:`repro.matching.match_many`.

A graph may additionally carry a **CSC mirror** (:meth:`DeviceCSR.with_csc`):
the row-major twin ``rxadj``/``radj`` plus the edge-parallel ``erow`` view and
the permutation ``eperm`` mapping each row-sorted edge back to its CSR slot.
The mirror is what the direction-optimizing pull sweep
(``MatcherConfig(dirop=True)``) gathers over; it is lazily built, stays
``None`` by default (zero cost for push-only workloads), and is threaded
through every shape operation (``pad_to``/``pad_vertices``/``bucketed``/
``stack``/``shard``) so an admitted serving graph keeps it.  Presence is part
of :attr:`bucket_key` — a mirrored graph compiles a different program than a
bare one, and the cache must see that.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # avoid a circular import; only needed for annotations
    from repro.core.csr import BipartiteCSR

LANE = 128  # TPU lane width; every edge capacity is a multiple of this


class GraphValidationError(ValueError):
    """A graph's CSR arrays violate the structural invariants every kernel
    assumes (monotone offsets, in-range endpoints, sentinel tail discipline).

    Raised by :meth:`DeviceCSR.validate` and by serving admission
    (``Bucketizer(validate=True)``) so a malformed or adversarial graph is
    rejected before it can poison a batched dispatch.  ``problems`` keeps
    the full finding list; ``str()`` shows them all.
    """

    def __init__(self, problems: Sequence[str]):
        self.problems = tuple(problems)
        super().__init__("invalid bipartite CSR: " + "; ".join(self.problems))


def validate_structure(cxadj: np.ndarray, cadj: np.ndarray, ecol: np.ndarray,
                       nnz: int, nc: int, nr: int) -> Tuple[str, ...]:
    """Structural findings for one graph's host-side CSR arrays (empty tuple
    = valid).  The checks mirror what the kernels silently assume:

    * ``cxadj`` is (nc+1,), starts at 0, is monotone nondecreasing and ends
      at the true edge count ``nnz`` (<= the padded capacity);
    * real edge slots carry in-range endpoints (``cadj`` row ids < nr,
      ``ecol`` column ids < nc) and ``ecol`` agrees with the offsets (edge
      slot ``e`` of column ``c`` has ``ecol[e] == c``);
    * padding slots carry the inert sentinels ``cadj = nr`` / ``ecol = nc``
      — a padding edge with a real endpoint would propose phantom matches.

    Out-of-range ids would otherwise be CLAMPED by the solver's guarded
    gathers into silently-wrong matchings, which is exactly why admission
    runs this before upload.
    """
    problems = []
    cxadj = np.asarray(cxadj)
    cadj = np.asarray(cadj)
    ecol = np.asarray(ecol)
    nnz_pad = int(cadj.shape[-1])
    if cxadj.shape != (nc + 1,):
        return (f"cxadj shape {cxadj.shape} != ({nc + 1},)",)
    if ecol.shape != cadj.shape:
        return (f"ecol shape {ecol.shape} != cadj shape {cadj.shape}",)
    if not (0 <= nnz <= nnz_pad):
        return (f"nnz {nnz} outside [0, nnz_pad={nnz_pad}]",)
    if cxadj[0] != 0:
        problems.append(f"cxadj[0] = {int(cxadj[0])} != 0")
    if np.any(np.diff(cxadj) < 0):
        bad = int(np.argmax(np.diff(cxadj) < 0))
        problems.append(f"cxadj not monotone at column {bad}")
    elif cxadj[-1] != nnz:
        problems.append(f"cxadj[-1] = {int(cxadj[-1])} != nnz {nnz}")
    real_r, real_c = cadj[:nnz], ecol[:nnz]
    if np.any((real_r < 0) | (real_r >= nr)):
        bad = int(np.argmax((real_r < 0) | (real_r >= nr)))
        problems.append(
            f"cadj[{bad}] = {int(real_r[bad])} outside rows [0, {nr})")
    if np.any((real_c < 0) | (real_c >= nc)):
        bad = int(np.argmax((real_c < 0) | (real_c >= nc)))
        problems.append(
            f"ecol[{bad}] = {int(real_c[bad])} outside columns [0, {nc})")
    elif not problems and cxadj[-1] == nnz:
        want = np.repeat(np.arange(nc, dtype=ecol.dtype), np.diff(cxadj))
        if not np.array_equal(real_c, want):
            bad = int(np.argmax(real_c != want))
            problems.append(
                f"ecol[{bad}] = {int(real_c[bad])} disagrees with cxadj "
                f"(expected column {int(want[bad])})")
    if np.any(cadj[nnz:] != nr):
        bad = nnz + int(np.argmax(cadj[nnz:] != nr))
        problems.append(
            f"padding cadj[{bad}] = {int(cadj[bad])} != sentinel {nr}")
    if np.any(ecol[nnz:] != nc):
        bad = nnz + int(np.argmax(ecol[nnz:] != nc))
        problems.append(
            f"padding ecol[{bad}] = {int(ecol[bad])} != sentinel {nc}")
    return tuple(problems)


def bucket_nnz(nnz: int, lane: int = LANE) -> int:
    """Smallest power-of-two multiple of ``lane`` holding ``nnz`` edges."""
    cap = lane
    while cap < nnz:
        cap *= 2
    return cap


def per_shard_nnz(nnz_pad: int, ndev: int, lane: int = LANE) -> int:
    """Per-device edge capacity when sharding ``nnz_pad`` edges over ``ndev``
    devices: each shard is itself a canonical bucket.  Shared by
    :meth:`DeviceCSR.shard` and the collective cost model
    (``benchmarks/collective_report.py --matcher``)."""
    return bucket_nnz(-(-nnz_pad // ndev), lane)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceCSR:
    """Column-major CSR bipartite graph living on the accelerator.

    Data leaves (batchable): ``cxadj`` (nc+1,), ``cadj``/``ecol``
    (nnz_pad,), ``nnz`` scalar int32.  Static metadata: ``nc``, ``nr``.

    Optional CSC mirror leaves (all present or all ``None``, see
    :meth:`with_csc`): ``rxadj`` (nr+1,) row offsets into the row-sorted edge
    list, ``radj``/``erow`` (nnz_pad,) column/row endpoints in row-sorted
    order, ``eperm`` (nnz_pad,) the CSR position of each row-sorted edge.
    Sentinel conventions match the CSR side (``radj = nc``, ``erow = nr``).
    """

    cxadj: jax.Array
    cadj: jax.Array
    ecol: jax.Array
    nnz: jax.Array
    nc: int = dataclasses.field(metadata=dict(static=True))
    nr: int = dataclasses.field(metadata=dict(static=True))
    rxadj: Optional[jax.Array] = None
    radj: Optional[jax.Array] = None
    erow: Optional[jax.Array] = None
    eperm: Optional[jax.Array] = None

    # -- shape/bucket introspection ------------------------------------------
    @property
    def nnz_pad(self) -> int:
        return int(self.cadj.shape[-1])

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return tuple(self.cadj.shape[:-1])

    @property
    def has_csc(self) -> bool:
        return self.rxadj is not None

    @property
    def bucket_key(self) -> Tuple:
        """The compile-relevant shape: (*batch, nc, nr, nnz_pad[, "csc"]).

        The mirror marker matters: a mirrored graph has extra pytree leaves,
        so the traced program differs and the compile cache (and the serving
        warmup grid) must key on its presence.
        """
        key = self.batch_shape + (self.nc, self.nr, self.nnz_pad)
        return key + ("csc",) if self.has_csc else key

    # -- host <-> device ------------------------------------------------------
    @classmethod
    def from_host(cls, g: "BipartiteCSR", pad_to: Optional[int] = None,
                  device=None) -> "DeviceCSR":
        """Upload a host graph, optionally repadding the edge capacity."""
        cadj, ecol = g.cadj, g.ecol
        if pad_to is not None and pad_to != g.nnz_pad:
            assert pad_to >= g.nnz, (pad_to, g.nnz)
            cadj = np.full(pad_to, g.nr, np.int32)
            ecol = np.full(pad_to, g.nc, np.int32)
            cadj[: g.nnz] = g.cadj[: g.nnz]
            ecol[: g.nnz] = g.ecol[: g.nnz]
        put = (lambda x: jax.device_put(x, device)) if device else jnp.asarray
        return cls(cxadj=put(np.asarray(g.cxadj, np.int32)),
                   cadj=put(np.asarray(cadj, np.int32)),
                   ecol=put(np.asarray(ecol, np.int32)),
                   nnz=put(np.int32(g.nnz)), nc=g.nc, nr=g.nr)

    def validate(self) -> "DeviceCSR":
        """Check the structural invariants (one host sync); returns ``self``
        so it chains, raises :class:`GraphValidationError` otherwise.

        Serving admission calls this via ``Bucketizer(validate=True)``; the
        corpus harness and tests call it directly on suspect graphs.
        """
        assert not self.batch_shape, "validate() takes a single graph"
        problems = validate_structure(self.cxadj, self.cadj, self.ecol,
                                      int(self.nnz), self.nc, self.nr)
        if problems:
            raise GraphValidationError(problems)
        return self

    def to_host(self) -> "BipartiteCSR":
        """Materialize back to the numpy container (one sync, for interop)."""
        from repro.core.csr import BipartiteCSR
        assert not self.batch_shape, "unstack a batched DeviceCSR first"
        return BipartiteCSR(nc=self.nc, nr=self.nr, nnz=int(self.nnz),
                            cxadj=np.asarray(self.cxadj),
                            cadj=np.asarray(self.cadj),
                            ecol=np.asarray(self.ecol))

    # -- the CSC mirror -------------------------------------------------------
    def with_csc(self) -> "DeviceCSR":
        """Attach the row-major mirror (no-op if already present).

        One stable ``argsort`` over the edge list: padding edges carry
        ``cadj = nr`` so they sort to the tail and stay inert sentinels in
        the mirror too (``radj = nc``, ``erow = nr``).  ``rxadj[r]`` is the
        first row-sorted slot of row ``r`` and ``rxadj[nr]`` the true edge
        count; ``eperm`` maps each row-sorted slot back to its CSR position
        (identity on the sentinel tail).  Build it *before* ``stack`` or
        ``shard`` — the mirror then rides every later shape operation.
        """
        if self.has_csc:
            return self
        assert not self.batch_shape, \
            "with_csc() takes a single graph; build the mirror before stack()"
        order = jnp.argsort(self.cadj, stable=True).astype(jnp.int32)
        erow = self.cadj[order]
        rxadj = jnp.searchsorted(
            erow, jnp.arange(self.nr + 1, dtype=jnp.int32)).astype(jnp.int32)
        return dataclasses.replace(self, rxadj=rxadj, radj=self.ecol[order],
                                   erow=erow, eperm=order)

    def drop_csc(self) -> "DeviceCSR":
        """Return the bare graph (the mirror leaves removed)."""
        return dataclasses.replace(self, rxadj=None, radj=None, erow=None,
                                   eperm=None)

    # -- bucketing ------------------------------------------------------------
    def pad_to(self, nnz_pad: int) -> "DeviceCSR":
        """Grow the edge capacity on device (sentinel-fill the new slots)."""
        cur = self.nnz_pad
        if nnz_pad == cur:
            return self
        assert nnz_pad > cur, f"cannot shrink edge capacity {cur} -> {nnz_pad}"
        extra = nnz_pad - cur
        pad_shape = self.batch_shape + (extra,)
        cadj = jnp.concatenate(
            [self.cadj, jnp.full(pad_shape, self.nr, jnp.int32)], axis=-1)
        ecol = jnp.concatenate(
            [self.ecol, jnp.full(pad_shape, self.nc, jnp.int32)], axis=-1)
        g = dataclasses.replace(self, cadj=cadj, ecol=ecol)
        if self.has_csc:
            # mirror sentinels live at the tail too; new slots map to the new
            # CSR tail slots (identity), keeping eperm a true permutation
            tail = cur + jnp.arange(extra, dtype=jnp.int32)
            g = dataclasses.replace(
                g,
                radj=jnp.concatenate(
                    [self.radj, jnp.full(pad_shape, self.nc, jnp.int32)],
                    axis=-1),
                erow=jnp.concatenate(
                    [self.erow, jnp.full(pad_shape, self.nr, jnp.int32)],
                    axis=-1),
                eperm=jnp.concatenate(
                    [self.eperm,
                     jnp.broadcast_to(tail, pad_shape)], axis=-1))
        return g

    def bucketed(self, lane: int = LANE) -> "DeviceCSR":
        """Round the edge capacity up to the canonical power-of-two bucket."""
        return self.pad_to(bucket_nnz(self.nnz_pad, lane))

    def pad_vertices(self, nc: int, nr: int) -> "DeviceCSR":
        """Grow the vertex counts on device (serving-bucketizer path).

        The extra columns/rows are isolated (no incident edges), so the
        maximum matching — and every solver trajectory on the real vertices —
        is unchanged.  Padding edges are re-sentineled (they encoded the old
        ``nc``/``nr``) and ``cxadj`` is extended with the terminal offset.
        Changes the static bucket shape, which is the point: the bucketizer
        maps many true sizes onto one declared compiled bucket.
        """
        if (nc, nr) == (self.nc, self.nr):
            return self
        assert not self.batch_shape, "pad_vertices() takes a single graph"
        assert nc >= self.nc and nr >= self.nr, \
            f"cannot shrink vertex counts {(self.nc, self.nr)} -> {(nc, nr)}"
        cxadj = self.cxadj
        if nc > self.nc:
            cxadj = jnp.concatenate(
                [cxadj, jnp.broadcast_to(cxadj[-1:], (nc - self.nc,))])
        cadj = jnp.where(self.cadj == self.nr, jnp.int32(nr), self.cadj)
        ecol = jnp.where(self.ecol == self.nc, jnp.int32(nc), self.ecol)
        g = dataclasses.replace(self, cxadj=cxadj, cadj=cadj, ecol=ecol,
                                nc=nc, nr=nr)
        if self.has_csc:
            rxadj = self.rxadj
            if nr > self.nr:
                # new rows are edgeless: offsets repeat the true edge count
                rxadj = jnp.concatenate(
                    [rxadj, jnp.broadcast_to(rxadj[-1:], (nr - self.nr,))])
            g = dataclasses.replace(
                g, rxadj=rxadj,
                radj=jnp.where(self.radj == self.nc, jnp.int32(nc),
                               self.radj),
                erow=jnp.where(self.erow == self.nr, jnp.int32(nr),
                               self.erow))
        return g

    # -- multi-device sharding ------------------------------------------------
    def shard(self, mesh, axis: str = "data") -> "DeviceCSR":
        """Edge-partition the graph over one mesh axis (for ShardedMatcher).

        The edge arrays (``ecol``/``cadj``) are 1-D sharded across the
        ``axis`` devices — each owns an equal contiguous slice — while the
        O(n) arrays (``cxadj``, ``nnz``) are replicated.  The edge capacity is
        padded so every shard is itself a canonical power-of-two bucket
        (:func:`bucket_nnz`): the result stays an ordinary ``DeviceCSR``
        pytree whose :attr:`bucket_key` is cacheable, and each per-device
        slice keeps the lane alignment the Pallas kernel tiles over.
        Padding edges carry sentinel endpoints and are inert, as everywhere;
        they accumulate at the tail, but the per-level sweep is a dense
        vector op over every lane of a shard, so work per device is exactly
        the shard capacity no matter how the real edges distribute.
        """
        assert not self.batch_shape, "shard() takes a single graph"
        from jax.sharding import NamedSharding, PartitionSpec as P
        ndev = int(mesh.shape[axis])
        per_shard = per_shard_nnz(self.nnz_pad, ndev)
        g = self if ndev * per_shard == self.nnz_pad \
            else self.pad_to(ndev * per_shard)
        edges = NamedSharding(mesh, P(axis))
        rep = NamedSharding(mesh, P())
        g = dataclasses.replace(
            g,
            ecol=jax.device_put(g.ecol, edges),
            cadj=jax.device_put(g.cadj, edges),
            cxadj=jax.device_put(g.cxadj, rep),
            nnz=jax.device_put(g.nnz, rep))
        if g.has_csc:
            # the row-sorted edge list shards 1-D like the CSR one: each
            # device owns a contiguous *row range* of the mirror (rows are
            # sorted), which is exactly what the per-shard pull sweep wants;
            # the O(n) offsets stay replicated.  Shard boundaries need not
            # align with the CSR shards — any edge partition min-merged with
            # the same per-level pmin yields the same winners.
            g = dataclasses.replace(
                g,
                radj=jax.device_put(g.radj, edges),
                erow=jax.device_put(g.erow, edges),
                eperm=jax.device_put(g.eperm, edges),
                rxadj=jax.device_put(g.rxadj, rep))
        return g

    # -- batching -------------------------------------------------------------
    @staticmethod
    def stack(graphs: Sequence["DeviceCSR"]) -> "DeviceCSR":
        """Stack same-bucket graphs into one batched DeviceCSR (for vmap)."""
        assert graphs, "empty graph batch"
        g0 = graphs[0]
        assert len({g.has_csc for g in graphs}) == 1, \
            "cannot stack mirrored and bare graphs; with_csc() all or none"
        cap = max(g.nnz_pad for g in graphs)
        graphs = [g.pad_to(cap) for g in graphs]
        for g in graphs:
            assert (g.nc, g.nr) == (g0.nc, g0.nr), \
                f"bucket mismatch: {(g.nc, g.nr)} vs {(g0.nc, g0.nr)}"
        return jax.tree.map(lambda *xs: jnp.stack(xs), *graphs)

    def unstack(self) -> Tuple["DeviceCSR", ...]:
        assert self.batch_shape, "not a batched DeviceCSR"
        n = self.batch_shape[0]
        return tuple(jax.tree.map(lambda x: x[i], self) for i in range(n))
