"""Matching state & stats as pytree dataclasses (device-resident results).

These replace the ad-hoc ``(cmatch, rmatch, stats-dict)`` tuple of the old
host-centric API: phases/fallbacks/cardinality stay as device scalars until
the caller explicitly asks (:meth:`MatchStats.as_dict`,
:meth:`MatchState.to_host`), so a matcher run composes under ``jit``/``vmap``
with zero forced syncs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = jnp.int32(-3)  # value of the trailing sentinel slot


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatchState:
    """Matching vectors with the solver's sentinel slot still attached.

    ``cmatch`` (nc+1,) / ``rmatch`` (nr+1,): matched partner or -1; the last
    slot is the kernels' scratch sentinel.  ``phases``/``fallbacks`` count the
    solver's outer iterations (0 for a freshly initialized state).
    ``certified`` is the solver's Berge certificate: True iff the last BFS
    phase proved no augmenting path remains, i.e. the matching is maximum —
    a ``MatcherConfig.max_phases``-truncated solve leaves it False (fresh
    and warm-started-only states are likewise uncertified).
    """

    cmatch: jax.Array
    rmatch: jax.Array
    phases: jax.Array
    fallbacks: jax.Array
    certified: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.bool_(False))

    @classmethod
    def fresh(cls, nc: int, nr: int, batch_shape: Tuple[int, ...] = ()
              ) -> "MatchState":
        """All-unmatched state for an (nc, nr) bucket (device arrays)."""
        cm = jnp.full(batch_shape + (nc + 1,), jnp.int32(-1))
        rm = jnp.full(batch_shape + (nr + 1,), jnp.int32(-1))
        cm = cm.at[..., nc].set(SENTINEL)
        rm = rm.at[..., nr].set(SENTINEL)
        zero = jnp.zeros(batch_shape, jnp.int32)
        return cls(cmatch=cm, rmatch=rm, phases=zero, fallbacks=zero,
                   certified=jnp.zeros(batch_shape, bool))

    @classmethod
    def from_host(cls, cmatch: np.ndarray, rmatch: np.ndarray) -> "MatchState":
        """Wrap true-size host vectors (appends the sentinel slot)."""
        cm = jnp.concatenate([jnp.asarray(cmatch, jnp.int32),
                              jnp.full((1,), SENTINEL)])
        rm = jnp.concatenate([jnp.asarray(rmatch, jnp.int32),
                              jnp.full((1,), SENTINEL)])
        zero = jnp.int32(0)
        return cls(cmatch=cm, rmatch=rm, phases=zero, fallbacks=zero,
                   certified=jnp.bool_(False))

    @property
    def cardinality(self) -> jax.Array:
        """Matched-pair count as a device scalar (no host sync)."""
        return jnp.sum((self.cmatch[..., :-1] >= 0).astype(jnp.int32),
                       axis=-1)

    def to_host(self) -> Tuple[np.ndarray, np.ndarray]:
        """(cmatch, rmatch) as true-size numpy arrays — the only host hop."""
        return (np.asarray(self.cmatch)[..., :-1],
                np.asarray(self.rmatch)[..., :-1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatchStats:
    """Run statistics; scalars stay on device until :meth:`as_dict`."""

    cardinality: jax.Array
    phases: jax.Array
    fallbacks: jax.Array
    certified: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.bool_(False))
    variant: str = dataclasses.field(default="", metadata=dict(static=True))

    @classmethod
    def of(cls, state: MatchState, variant: str = "") -> "MatchStats":
        return cls(cardinality=state.cardinality, phases=state.phases,
                   fallbacks=state.fallbacks, certified=state.certified,
                   variant=variant)

    def as_dict(self) -> dict:
        """Host-side stats dict (the old API's ``stats`` payload)."""
        out = {k: np.asarray(getattr(self, k))
               for k in ("phases", "fallbacks", "cardinality")}
        out = {k: int(v) if v.ndim == 0 else v.astype(int)
               for k, v in out.items()}
        cert = np.asarray(self.certified)
        out["certified"] = bool(cert) if cert.ndim == 0 else cert.astype(bool)
        out["variant"] = self.variant
        return out


def empty_like_graph(graph, batch_shape: Optional[Tuple[int, ...]] = None
                     ) -> MatchState:
    """Fresh all-unmatched state shaped for ``graph`` (a DeviceCSR)."""
    bs = graph.batch_shape if batch_shape is None else batch_shape
    return MatchState.fresh(graph.nc, graph.nr, bs)
