"""Version-compat shims for the multi-device matching path.

``shard_map`` moved twice across JAX releases (``jax.experimental.shard_map``
-> top-level ``jax.shard_map``) and its replication-checking kwarg was renamed
(``check_rep`` -> ``check_vma``).  The matcher's level-synchronous solve loop
is a ``lax.while_loop``, for which older releases have no replication rule, so
the check must be disabled.  This module centralizes both quirks; everything
else imports :func:`shard_map_no_check` from here instead of carrying its own
try/except (the old ``core/distributed.py`` did exactly that, inline).
"""
from __future__ import annotations

import inspect

try:                                       # jax >= 0.5 exposes it top-level
    from jax import shard_map as _shard_map
except ImportError:                        # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map

shard_map = _shard_map

_NO_CHECK_KW = None
for _kw in ("check_rep", "check_vma"):
    if _kw in inspect.signature(_shard_map).parameters:
        _NO_CHECK_KW = _kw
        break


def shard_map_no_check(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off (needed for while_loop
    bodies), under whichever kwarg name this JAX release uses."""
    kw = {_NO_CHECK_KW: False} if _NO_CHECK_KW else {}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
