"""Distributed-memory matching: the edge-partitioned matcher over a mesh.

The paper closes with: "an out-of-core or distributed-memory type algorithm is
amenable when the graph does not fit into the device ... We plan to
investigate the techniques to obtain good matching performance for
extreme-scale bipartite graphs."  :class:`ShardedMatcher` is that algorithm,
and it is the *same* solver as the single-device :class:`~repro.matching.api.
Matcher` — :func:`repro.matching.solve.make_solver` with a mesh axis bound:

* the edge list is 1-D sharded across one mesh axis
  (:meth:`DeviceCSR.shard`); each device owns ``nnz/D`` edges — the natural
  scale-out of the paper's CT strided edge ownership;
* the O(n) BFS state (``bfs``/``root``/``pred``/``cmatch``/``rmatch``) is
  replicated; every level each device sweeps its own edge shard into a local
  per-row winner vector (the fused Pallas ``frontier_expand_fused`` kernel
  when ``config.use_pallas`` — each shard's min-merge happens inside its
  kernel — the jnp sweep + scatter otherwise) and the shard winners merge
  with one ``jax.lax.pmin`` — a single all-reduce per BFS level, the
  minimal coordination any level-synchronous distributed BFS needs;
* ``ALTERNATE``/``FIXMATCHING`` act on replicated O(n) state and therefore
  run redundantly-but-identically on every device (cheaper than sharding
  them: their cost is O(n) per phase vs O(nnz/D) for expansion).

Communication per level = one pmin over an (nr+1) int32 vector; a ring
all-reduce moves ``2*(D-1)/D * 4*(nr+1)`` bytes per link
(``benchmarks/collective_report.py --matcher`` prices this, and
``docs/architecture.md`` walks through the whole design).

The warm start runs *outside* the ``shard_map`` region, as plain jnp inside
the same jitted program: GSPMD partitions its scatter/gather rounds over the
sharded edge arrays automatically, so every registry entry
(``none``/``cheap``/``karp_sipser``/custom) works unmodified.  Compiled
programs live in the shared compile cache, keyed additionally on the mesh
fingerprint and axis name.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ._compat import shard_map_no_check
from .api import Matcher
from .cache import compile_cache_key, get_compiled
from .config import MatcherConfig
from .device_csr import DeviceCSR
from .solve import make_solver
from .state import MatchState, MatchStats, empty_like_graph
from .warmstart import get_warm_start


def mesh_cache_key(mesh: Mesh, axis: str):
    """Hashable mesh identity for the compile cache.

    Two meshes force distinct programs iff they differ in axis layout or
    member devices; both are captured here (device ids, not object ids, so a
    re-built but identical mesh still hits).
    """
    return (tuple(mesh.shape.items()),
            tuple(int(d.id) for d in mesh.devices.flat), axis)


class ShardedMatcher(Matcher):
    """A paper variant + warm start, compiled per (size bucket, mesh, axis).

    >>> mesh = jax.make_mesh((4,), ("data",))
    >>> m = ShardedMatcher(mesh, config=MatcherConfig(algo="apfb"),
    ...                    warm_start="cheap")
    >>> state = m.run(DeviceCSR.from_host(g).shard(mesh, "data"))
    >>> int(state.cardinality)          # == single-device Matcher.run

    Inherits the single-device facade: ``init``/``solve``/``stats`` and the
    state checks are shared; only ``run`` is replaced with the
    ``shard_map``-wrapped program (``run_many`` is not supported — batching
    and edge-sharding compose via one graph per mesh instead).
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 config: MatcherConfig = MatcherConfig(),
                 warm_start: str = "none"):
        super().__init__(config, warm_start)
        if self.config.adaptive_frontier:
            raise ValueError(
                "adaptive_frontier is single-device only; ShardedMatcher "
                "keeps the dense per-shard sweep + one pmin per level "
                "(use MatcherConfig(dirop=True) for a direction heuristic "
                "that composes with sharding)")
        assert axis in mesh.axis_names, (axis, mesh.axis_names)
        self.mesh = mesh
        self.axis = axis

    def run(self, graph: DeviceCSR, state: Optional[MatchState] = None
            ) -> MatchState:
        """Maximum matching with edges sharded over the mesh axis.

        ``graph`` is re-sharded if needed (:meth:`DeviceCSR.shard` is a no-op
        on an already edge-partitioned graph of the right capacity).  As with
        the single-device path, ``state=None`` fuses warm start + solve into
        one compiled program; an explicit state resumes the solver from it.
        """
        assert not graph.batch_shape, \
            "ShardedMatcher.run takes a single (edge-sharded) graph"
        if self.config.dirop and not graph.has_csc:
            raise ValueError(
                "MatcherConfig(dirop=True) needs the CSC mirror; call "
                "graph.with_csc() before .shard() — the mirror shards with "
                "the graph")
        graph = graph.shard(self.mesh, self.axis)
        cold = state is None
        if cold:
            state = empty_like_graph(graph)
        ws = self._cache_tag(cold)
        key = compile_cache_key(
            graph.bucket_key, self.config, ws,
            ("sharded_run",) + mesh_cache_key(self.mesh, self.axis))
        dirop = self.config.dirop

        def build():
            solve = make_solver(self.config, axis=self.axis)
            # dirop extends the solver args with the column offsets and the
            # CSC mirror: O(n) offsets replicated, the row-sorted edge
            # arrays 1-D sharded exactly like the CSR ones
            in_specs = (P(self.axis), P(self.axis), P(), P())
            if dirop:
                in_specs += (P(), P(), P(self.axis), P(self.axis))
            smap = shard_map_no_check(
                solve, self.mesh, in_specs=in_specs,
                out_specs=(P(), P(), P(), P(), P()))
            init = get_warm_start(self.warm_start)
            cfg = self.config

            def fn(g: DeviceCSR, s: MatchState) -> MatchState:
                self._check_state(g, s)
                cm, rm = s.cmatch, s.rmatch
                if cold:
                    cm, rm = init(g.ecol, g.cadj, cm, rm)
                extra = ((g.cxadj, g.rxadj, g.radj, g.erow) if dirop else ())
                cm, rm, phases, fb, cert = smap(g.ecol, g.cadj, cm, rm,
                                                *extra)
                if cfg.degrade_maximal and cfg.max_phases > 0:
                    # Same budget-exhausted maximality repair as the
                    # single-device solver, applied OUTSIDE the shard_map
                    # region: cheap_init's scatter rounds need the whole
                    # edge list, and like the warm start GSPMD partitions
                    # them over the sharded arrays automatically.
                    from .warmstart import cheap_init
                    cm, rm = jax.lax.cond(
                        cert, lambda cr: cr,
                        lambda cr: cheap_init(g.ecol, g.cadj, *cr),
                        (cm, rm))
                return MatchState(cmatch=cm, rmatch=rm,
                                  phases=s.phases + phases,
                                  fallbacks=s.fallbacks + fb,
                                  certified=cert)

            return fn

        return get_compiled(key, build)(graph, state)

    def run_many(self, graphs, states=None):
        raise NotImplementedError(
            "ShardedMatcher shards edges over the mesh; batch with "
            "Matcher.run_many or one ShardedMatcher call per graph")

    def stats(self, state: MatchState) -> MatchStats:
        ndev = int(self.mesh.shape[self.axis])
        return MatchStats.of(state, f"sharded-{self.config.name}@{ndev}")


def match_sharded(graph: DeviceCSR, mesh: Mesh, axis: str = "data",
                  config: MatcherConfig = MatcherConfig(),
                  warm_start: str = "cheap",
                  state: Optional[MatchState] = None) -> MatchState:
    """Functional alias: ``ShardedMatcher(mesh, axis, config, ws).run(...)``."""
    return ShardedMatcher(mesh, axis, config, warm_start).run(graph, state)
