"""Pluggable warm-start registry: pure on-device matching initializers.

Every entry is a pure function ``(ecol, cadj, cmatch, rmatch) ->
(cmatch, rmatch)`` over sentinel-padded int32 vectors, so
:meth:`repro.matching.Matcher.run` can fuse *init + solve* into one compiled
program — the warm start never round-trips through the host (the old
``cheap_matching_jax``/``karp_sipser_jax`` wrappers did numpy in/out between
init and matcher).

Built-ins: ``"none"`` (cold), ``"cheap"`` (the paper's greedy warm start),
``"karp_sipser"`` (beyond-paper degree-1 peeling + greedy residual).  Register
custom initializers with :func:`register_warm_start`.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .solve import IINF, _fix_matching, scatter_min

InitFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array],
                  Tuple[jax.Array, jax.Array]]


def none_init(ecol, cadj, cmatch, rmatch):
    """Cold start: pass the incoming (all-unmatched) state through."""
    del ecol, cadj
    return cmatch, rmatch


def cheap_init(ecol, cadj, cmatch, rmatch):
    """Parallel cheap matching (the paper's common warm start).

    Speculative round-based greedy (propose -> resolve -> commit): each round
    every unmatched column proposes its lowest-index unmatched neighbor row;
    each proposed row accepts its lowest proposing column; accepted pairs
    commit.  Rounds repeat until no proposal survives -> a maximal greedy
    matching (quality comparable to sequential cheap matching).
    """
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1

    def round_fn(carry):
        cmatch, rmatch, _ = carry
        col_free = cmatch[ecol] == -1
        row_free = rmatch[cadj] == -1
        cand = jnp.where(col_free & row_free, cadj, IINF)
        best_r = scatter_min(nc, ecol, cand)
        cols = jnp.arange(nc + 1, dtype=jnp.int32)
        propose = best_r < IINF
        best_c = scatter_min(nr, jnp.where(propose, best_r, nr),
                             jnp.where(propose, cols, IINF))
        won = best_c < IINF                                  # per-row accept
        rows = jnp.arange(nr + 1, dtype=jnp.int32)
        rmatch = jnp.where(won, best_c, rmatch)
        cmatch = cmatch.at[jnp.where(won, best_c, nc)].set(
            jnp.where(won, rows, cmatch[nc]))
        cmatch = cmatch.at[nc].set(jnp.int32(-3))
        return cmatch, rmatch, jnp.any(won)

    def cond(carry):
        return carry[-1]

    cmatch, rmatch, _ = jax.lax.while_loop(
        cond, round_fn, (cmatch, rmatch, jnp.bool_(True)))
    return cmatch, rmatch


def karp_sipser_init(ecol, cadj, cmatch, rmatch):
    """Karp–Sipser peeling, data-parallel (beyond the paper's cheap init).

    While the residual graph has a degree-1 vertex, matching its only edge is
    optimal; the TPU adaptation peels *all* current degree-1 vertices per
    round (speculatively) with min-scatter conflict resolution, then finishes
    with the parallel cheap matching on the residual and a repair pass.  All
    three stages fuse into the caller's program — no host hop.
    """
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1

    def degree_round(carry):
        cmatch, rmatch, _ = carry
        alive = (cmatch[ecol] == -1) & (rmatch[cadj] == -1)
        one = jnp.int32(1)
        cdeg = jnp.zeros(nc + 1, jnp.int32).at[
            jnp.where(alive, ecol, nc)].add(one)
        rdeg = jnp.zeros(nr + 1, jnp.int32).at[
            jnp.where(alive, cadj, nr)].add(one)
        # forced edges: endpoint with residual degree 1
        forced = alive & ((cdeg[ecol] == 1) | (rdeg[cadj] == 1))

        # speculative commit of all forced edges, min-scatter per column/row
        prop_r = scatter_min(nc, jnp.where(forced, ecol, nc),
                             jnp.where(forced, cadj, IINF))
        col_has = prop_r < IINF
        # rows accept lowest proposing column among columns that picked them
        cols = jnp.arange(nc + 1, dtype=jnp.int32)
        prop_c = scatter_min(nr, jnp.where(col_has, prop_r, nr),
                             jnp.where(col_has, cols, IINF))
        rows = jnp.arange(nr + 1, dtype=jnp.int32)
        won_r = prop_c < IINF                       # row r matched to prop_c[r]
        rmatch = jnp.where(won_r & (rmatch == -1), prop_c, rmatch)
        # commit winning columns (repair: only pairs where row accepted col)
        won_pair = won_r & (rmatch == prop_c)
        cmatch = cmatch.at[jnp.where(won_pair, jnp.clip(prop_c, 0, nc), nc)
                           ].max(jnp.where(won_pair, rows, jnp.int32(-1)))
        cmatch = cmatch.at[nc].set(jnp.int32(-3))
        rmatch = rmatch.at[nr].set(jnp.int32(-3))
        return cmatch, rmatch, jnp.any(forced)

    def cond(carry):
        return carry[-1]

    cmatch, rmatch, _ = jax.lax.while_loop(
        cond, degree_round, (cmatch, rmatch, jnp.bool_(True)))
    cmatch, rmatch = cheap_init(ecol, cadj, cmatch, rmatch)
    # clear asymmetric remnants of the speculative commits (same symmetric
    # repair the solver uses; the -2 endpoint clear is a no-op here)
    return _fix_matching(cmatch, rmatch)


WARM_STARTS: dict = {
    "none": none_init,
    "cheap": cheap_init,
    "karp_sipser": karp_sipser_init,
}
_VERSIONS: dict = {name: 0 for name in WARM_STARTS}


def register_warm_start(name: str, fn: InitFn) -> None:
    """Add a custom initializer to the registry (pure device fn required).

    Re-registering a name bumps its version so compiled programs built from
    the previous initializer are not reused.
    """
    if not callable(fn):
        raise TypeError(f"warm start {name!r} must be callable")
    WARM_STARTS[name] = fn
    _VERSIONS[name] = _VERSIONS.get(name, -1) + 1


def warm_start_version(name: str) -> int:
    """Monotonic per-name counter; part of the compile-cache key."""
    return _VERSIONS.get(name, 0)


def warm_start_names() -> tuple:
    return tuple(WARM_STARTS)


def get_warm_start(name: str) -> InitFn:
    try:
        return WARM_STARTS[name]
    except KeyError:
        raise KeyError(
            f"unknown warm start {name!r}; registered: {warm_start_names()}"
        ) from None
