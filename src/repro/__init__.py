"""repro: the paper's bipartite-matching system + LM substrate, in JAX."""
__version__ = "0.1.0"
