from .matching_router import (route_matching, route_matching_exact,
                              route_topk, router_stats)

__all__ = ["route_matching", "route_matching_exact", "route_topk",
           "router_stats"]
