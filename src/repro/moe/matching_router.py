"""Token -> expert assignment as maximum-cardinality bipartite b-matching.

This is the paper's algorithm applied to MoE routing (the framework
integration).  Token/expert assignment under expert capacity is a bipartite
b-matching problem: tokens have demand ``k`` (top-k routing), experts have
capacity ``C``, edges are each token's top-m candidate experts.  The greedy
capacity-truncation router (``route_topk``, the GShard/Switch standard) drops
every (token, choice) that lands on a full expert; maximum-cardinality
matching minimizes drops over the candidate graph.

The matcher here is the paper's APFB machinery specialized to the capacitated
case, with the same three phases per iteration:

* level-synchronous BFS from demand-deficient tokens through
  (token -> candidate expert -> tokens assigned to that expert -> ...) until
  experts with slack are found (the paper's GPUBFS, with experts playing the
  role of columns and "unmatched row" = expert with residual capacity);
* speculative parallel alternation of the discovered augmenting paths
  (ALTERNATE): every slack expert walks its predecessor chain in lock-step,
  swapping assignments; conflicting walkers are tolerated;
* a repair pass (FIXMATCHING): duplicate experts within a token are cleared
  and per-expert overflow is evicted by slot rank, restoring feasibility.

Everything is fixed-shape and jit-compatible, so the router runs inside the
training step.  ``aug_phases`` bounds the augmentation work (2 is enough to
recover most drops; benchmarks/table_router.py sweeps it).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.matching import DeviceCSR, Matcher, MatcherConfig
from repro.matching.solve import IINF

NEG = -1e30


def _slot_and_evict(assign, n_experts: int, capacity: int):
    """Final feasibility pass: slot = rank of instance within its expert
    (token-major priority, as in GShard); instances with slot >= C dropped."""
    T, k = assign.shape
    flat = assign.reshape(T * k)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)   # (I, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    slot = jnp.take_along_axis(
        ranks, jnp.clip(flat, 0, n_experts - 1)[:, None], axis=1)[:, 0]
    keep = (flat >= 0) & (slot < capacity)
    flat = jnp.where(keep, flat, -1)
    slot = jnp.where(keep, slot, 0)
    return flat.reshape(T, k), slot.reshape(T, k)


def _dedupe(assign):
    """Clear duplicate experts within a token (keep first occurrence)."""
    T, k = assign.shape
    dup = jnp.zeros((T, k), bool)
    for j in range(1, k):
        same = (assign[:, j:j + 1] == assign[:, :j]) & (assign[:, j:j + 1] >= 0)
        dup = dup.at[:, j].set(same.any(axis=1))
    return jnp.where(dup, -1, assign)


def _loads(assign, n_experts: int):
    flat = assign.reshape(-1)
    seg = jnp.where(flat >= 0, flat, n_experts)
    return jnp.zeros(n_experts + 1, jnp.int32).at[seg].add(1)[:n_experts]


def route_topk(logits, k: int, capacity: int):
    """Greedy baseline: per-choice-round capacity truncation (GShard-style)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, cand = jax.lax.top_k(logits, k)                          # (T, k)
    assign, slot = _slot_and_evict(cand, E, capacity)
    p = jnp.take_along_axis(probs, jnp.clip(cand, 0, E - 1), axis=1)
    p = jnp.where(assign >= 0, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-9)
    return assign, slot, p


def route_matching(logits, k: int, capacity: int, *, n_cand: int = 0,
                   aug_phases: int = 2, max_path: int = 8):
    """Capacitated maximum-cardinality matching router (the paper's technique).

    Returns (assign (T,k), slot (T,k), combine_probs (T,k)).
    """
    T, E = logits.shape
    m = n_cand or min(E, k + 2)                                 # candidate fan-out
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, cand = jax.lax.top_k(logits, m)                          # (T, m)

    # ---- phase 0: cascade greedy (the "cheap matching" warm start) --------
    # choice round j: every token with an unmet demand slot proposes its best
    # not-yet-used candidate; experts accept up to remaining capacity.
    assign = jnp.full((T, k), jnp.int32(-1))
    used = jnp.zeros((T, m), bool)                              # candidate consumed
    load = jnp.zeros(E, jnp.int32)
    for j in range(k + 2):                                      # k + retry rounds
        deficit = (assign >= 0).sum(-1) < k
        # best unused candidate with residual capacity
        cap_ok = (load[jnp.clip(cand, 0, E - 1)] < capacity) & ~used
        choice = jnp.argmax(cap_ok, axis=1)                     # first viable
        viable = jnp.take_along_axis(cap_ok, choice[:, None], 1)[:, 0] & deficit
        e_prop = jnp.where(
            viable, jnp.take_along_axis(cand, choice[:, None], 1)[:, 0], E)
        # experts accept by token-major rank within remaining capacity
        onehot = jax.nn.one_hot(e_prop, E + 1, dtype=jnp.int32)[:, :E]
        rank = jnp.cumsum(onehot, axis=0) - onehot
        myrank = jnp.take_along_axis(
            rank, jnp.clip(e_prop, 0, E - 1)[:, None], 1)[:, 0]
        accept = viable & (load[jnp.clip(e_prop, 0, E - 1)] + myrank < capacity)
        # commit: first free demand slot
        free_slot = jnp.argmax(assign < 0, axis=1)
        assign = jnp.where(
            accept[:, None]
            & (jnp.arange(k)[None, :] == free_slot[:, None]),
            e_prop[:, None], assign)
        used = used | (accept[:, None] & (jnp.arange(m)[None] == choice[:, None]))
        # a proposed-but-rejected candidate is NOT consumed (expert may free up
        # during augmentation) — but to guarantee round progress we consume it
        # after the k-th round:
        if j >= k:
            used = used | (viable[:, None] & (jnp.arange(m)[None] == choice[:, None]))
        load = _loads(assign, E)

    # ---- augmentation phases (APFB adapted; BFS + speculative alternate) ---
    for _ in range(aug_phases):
        load = _loads(assign, E)
        deficit = (assign >= 0).sum(-1) < k
        has_unused = (~used & (cand < E)).any(-1)
        start_t = deficit & has_unused
        # BFS over (token, expert) alternating structure
        t_level = jnp.where(start_t, 0, IINF)                   # (T,)
        e_level = jnp.full(E, IINF)
        pred_e = jnp.full(E, IINF)                              # token that enters e
        pred_t = jnp.full(T, IINF)                              # expert t releases
        endpoint = jnp.full(E, False)
        level = 0
        for level in range(0, max_path, 2):
            frontier_t = t_level == level
            # frontier tokens propose all unused candidates
            prop_src = jnp.where(frontier_t[:, None] & ~used, cand, E)
            tok_ids = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[:, None], (T, m))
            new_e = jnp.full(E + 1, IINF).at[prop_src.reshape(-1)].min(
                tok_ids.reshape(-1))[:E]
            fresh_e = (new_e < IINF) & (e_level == IINF)
            pred_e = jnp.where(fresh_e, new_e, pred_e)
            e_level = jnp.where(fresh_e, level + 1, e_level)
            endpoint = endpoint | (fresh_e & (load < capacity))
            # tokens assigned to freshly visited (full) experts join frontier
            assigned_fresh = (fresh_e & (load >= capacity))[
                jnp.clip(assign, 0, E - 1)] & (assign >= 0)     # (T, k)
            t_new = assigned_fresh.any(-1) & (t_level == IINF)
            which = jnp.argmax(assigned_fresh, axis=1)
            rel = jnp.take_along_axis(assign, which[:, None], 1)[:, 0]
            pred_t = jnp.where(t_new, rel, pred_t)
            t_level = jnp.where(t_new, level + 2, t_level)
        # ---- speculative parallel alternation from slack endpoints --------
        e_ids = jnp.arange(E, dtype=jnp.int32)
        cur_e = jnp.where(endpoint, e_ids, -1)                  # walker per expert
        gain_e = jnp.where(endpoint, e_ids, -1)                 # expert to add
        for _ in range(max_path // 2 + 1):
            active = cur_e >= 0
            t = jnp.where(active, pred_e[jnp.clip(cur_e, 0, E - 1)], IINF)
            t = t.astype(jnp.int32)
            valid = active & (t < T)
            tc = jnp.clip(t, 0, T - 1)
            release = pred_t[tc].astype(jnp.int32)              # expert released
            is_root = t_level[tc] == 0
            # swap: in token t, replace `release` by `gain_e` (root: fill a
            # free slot instead). Conflicts (two walkers, same token) resolve
            # by later-writer; repair pass restores feasibility.
            gain = jnp.where(valid, gain_e, -1)
            upd_root = valid & is_root
            upd_swap = valid & ~is_root & (release < E)
            # scatter per token: one walker wins (min expert id)
            tok_gain = jnp.full(T + 1, IINF).at[
                jnp.where(valid, tc, T)].min(jnp.where(valid, gain, IINF))[:T]
            tok_rel = jnp.full(T + 1, IINF).at[
                jnp.where(upd_swap, tc, T)].min(
                    jnp.where(upd_swap, release, IINF))[:T]
            win = tok_gain < IINF
            # apply swap / fill
            def apply_tok(assign):
                rel_match = assign == tok_rel[:, None]
                first_rel = (jnp.cumsum(rel_match, 1) == 1) & rel_match
                swapped = jnp.where(
                    win[:, None] & (tok_rel < IINF)[:, None] & first_rel,
                    tok_gain[:, None].astype(jnp.int32), assign)
                free = swapped < 0
                first_free = (jnp.cumsum(free, 1) == 1) & free
                filled = jnp.where(
                    win[:, None] & (tok_rel == IINF)[:, None] & first_free,
                    tok_gain[:, None].astype(jnp.int32), swapped)
                return filled
            assign = apply_tok(assign)
            # continue walk: released expert becomes the next gain
            nxt = jnp.where(upd_swap, release, -1)
            cur_e = jnp.where(valid & ~is_root, nxt, -1)
            gain_e = cur_e
        assign = _dedupe(assign)

    assign, slot = _slot_and_evict(assign, E, capacity)
    p = jnp.take_along_axis(probs, jnp.clip(assign, 0, E - 1), axis=1)
    p = jnp.where(assign >= 0, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-9)
    return assign, slot, p


def route_matching_exact(logits, k: int, capacity: int, *, n_cand: int = 0,
                         config: Optional[MatcherConfig] = None):
    """Exact maximum-cardinality routing via the core matcher (device API).

    The capacitated instance (token demand ``k``, expert capacity ``C``,
    each token usable at most once per expert) is reduced to plain bipartite
    matching with the classic degree-constrained-subgraph gadget: every
    (token, candidate-expert) pair gets a gadget node pair ``u``/``v`` where
    ``u`` (row) sees the token's ``k`` demand clones, ``v`` (column) sees
    ``u`` plus the expert's ``C`` slots.  A maximum matching then uses each
    gadget at most once — duplicate experts per token are structurally
    impossible — and its cardinality is ``T*m`` + the number of routed
    (token, expert) pairs, so maximum matching = minimum drops.

    The graph is built as a :class:`DeviceCSR` *inside the traced program*
    and solved with the public :class:`Matcher` facade (cheap warm start
    fused with APFB), so the router shares the paper's matcher core instead
    of re-implementing BFS/ALTERNATE.  Edge count is ``T*m*(k+1+C)`` —
    linear in capacity, but the gold-standard path is still meant for
    modest shapes; ``route_matching`` above is the fixed-phase approximation
    for production step loops.  Returns (assign (T,k), slot (T,k),
    combine_probs (T,k)) like the other routers.
    """
    T, E = logits.shape
    m = n_cand or min(E, k + 2)
    C = capacity
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, cand = jax.lax.top_k(logits, m)                          # (T, m)
    cand = cand.astype(jnp.int32)

    # columns: [T*k token clones | T*m gadget v-nodes]
    # rows:    [T*m gadget u-nodes | E*C expert slots]
    nc = T * k + T * m
    nr = T * m + E * C
    # clone edges: clone (t, j) -> u_(t, c) for every candidate c
    clone_ids = jnp.arange(T * k, dtype=jnp.int32)
    ecol_clone = jnp.repeat(clone_ids, m)
    cadj_clone = ((clone_ids // k)[:, None] * m
                  + jnp.arange(m, dtype=jnp.int32)).reshape(-1)
    # gadget edges: v_(t, c) -> u_(t, c), then every slot of expert cand[t, c]
    v_cols = T * k + jnp.arange(T * m, dtype=jnp.int32)
    ecol_v = jnp.repeat(v_cols, 1 + C)
    slot_rows = (T * m + cand.reshape(-1)[:, None] * C
                 + jnp.arange(C, dtype=jnp.int32))              # (T*m, C)
    cadj_v = jnp.concatenate(
        [jnp.arange(T * m, dtype=jnp.int32)[:, None], slot_rows],
        axis=1).reshape(-1)
    ecol = jnp.concatenate([ecol_clone, ecol_v])
    cadj = jnp.concatenate([cadj_clone, cadj_v])
    degrees = jnp.concatenate([jnp.full(T * k, m, jnp.int32),
                               jnp.full(T * m, 1 + C, jnp.int32)])
    cxadj = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(degrees)])
    graph = DeviceCSR(cxadj=cxadj.astype(jnp.int32), cadj=cadj, ecol=ecol,
                      nnz=jnp.int32(ecol.shape[0]), nc=nc, nr=nr)

    matcher = Matcher(config or MatcherConfig(), warm_start="cheap")
    state = matcher.run(graph)

    # gadget (t, c) routed iff its v-column matched an expert slot AND its
    # u-row matched a token clone — a maximum matching may park a lone v on
    # a slot without clone backing (same cardinality), which must not route
    v_match = state.cmatch[T * k: T * k + T * m].reshape(T, m)
    u_match = state.rmatch[: T * m].reshape(T, m)
    used = (v_match >= T * m) & (u_match >= 0) & (u_match < T * k)  # (T, m)
    # compact each token's routed candidates into its k demand slots; the
    # u-backing check above bounds per-token used count by the k clones
    pos = jnp.cumsum(used.astype(jnp.int32), axis=1) - 1        # rank among used
    dest = jnp.where(used, jnp.minimum(pos, k), k)
    assign = jnp.full((T, k + 1), jnp.int32(-1)).at[
        jnp.arange(T, dtype=jnp.int32)[:, None], dest].set(
            jnp.where(used, cand, -1))[:, :k]
    assign, slot = _slot_and_evict(assign, E, C)
    p = jnp.take_along_axis(probs, jnp.clip(assign, 0, E - 1), axis=1)
    p = jnp.where(assign >= 0, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-9)
    return assign, slot, p


def router_stats(assign, k: int) -> dict:
    """Drop-rate diagnostics (used by benchmarks and tests)."""
    T = assign.shape[0]
    assigned = (assign >= 0).sum()
    return {
        "assigned": assigned,
        "demand": T * k,
        "drop_rate": 1.0 - assigned / (T * k),
    }
