from .adamw import adamw_init, adamw_update, OptConfig
from .compress import compress_grads, decompress_grads

__all__ = ["adamw_init", "adamw_update", "OptConfig", "compress_grads",
           "decompress_grads"]
