"""AdamW with fp32 master state, global-norm clipping and ZeRO-1 sharding.

Optimizer state (m, v, master fp32 copy) is sharded over BOTH mesh axes
(ZeRO-1): each param's spec gets its first unsharded axis assigned to the
data axis when divisible.  With bf16 params this keeps nemotron-340b's
optimizer at ~16 GB/chip on the 16x16 mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import AX_DATA


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    master_fp32: bool = True
    factored: bool = False      # Adafactor-style row/col second moment +
                                # bf16 first moment: ~1/6 the optimizer bytes,
                                # required to fit the >=100B archs on v5e-256.


def _flat_axes(parts):
    out = set()
    for p in parts:
        if p is None:
            continue
        out.update((p,) if isinstance(p, str) else p)
    return out


def _zero1_spec(spec: P, shape) -> P:
    """Shard the first unsharded, divisible axis over data (ZeRO-1)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if AX_DATA in _flat_axes(parts):
        return P(*parts)                 # FSDP already uses the data axis
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % 2 == 0:     # divisibility resolved at sanitize
            parts[i] = AX_DATA
            return P(*parts)
    return P(*parts)


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adamw_init(params, specs, cfg: OptConfig):
    if cfg.factored:
        def mk_m(p):
            return jnp.zeros(p.shape, jnp.bfloat16)

        def mk_vr(p):   # row second moment (last dim reduced)
            if _factorable(p.shape):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def mk_vc(p):   # col second moment (second-to-last reduced)
            if _factorable(p.shape):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        def sp_reduce(s, p, drop_last: bool):
            parts = list(s) + [None] * (len(p.shape) - len(s))
            if not _factorable(p.shape):
                return P(*parts) if drop_last else P(None)
            if drop_last:
                return P(*parts[:-1])
            return P(*(parts[:-2] + parts[-1:]))

        state = {
            "m": jax.tree.map(mk_m, params),
            "vr": jax.tree.map(mk_vr, params),
            "vc": jax.tree.map(mk_vc, params),
            "step": jnp.int32(0),
        }
        sspecs = {
            "m": jax.tree.map(lambda s, p: _zero1_spec(s, p.shape), specs,
                              params, is_leaf=lambda s: isinstance(s, P)),
            "vr": jax.tree.map(lambda s, p: sp_reduce(s, p, True), specs,
                               params, is_leaf=lambda s: isinstance(s, P)),
            "vc": jax.tree.map(lambda s, p: sp_reduce(s, p, False), specs,
                               params, is_leaf=lambda s: isinstance(s, P)),
            "step": P(),
        }
        return state, sspecs

    def mk(p):
        return jnp.zeros(p.shape, jnp.float32)

    state = {
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(mk, params),
        "step": jnp.int32(0),
    }
    sspecs = {
        "m": jax.tree.map(lambda s, p: _zero1_spec(s, p.shape), specs, params,
                          is_leaf=lambda s: isinstance(s, P)),
        "v": jax.tree.map(lambda s, p: _zero1_spec(s, p.shape), specs, params,
                          is_leaf=lambda s: isinstance(s, P)),
        "step": P(),
    }
    if cfg.master_fp32:
        # jnp.array(copy=True): astype(f32) on f32 params would alias the
        # param buffer and break donation (same buffer donated twice)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        sspecs["master"] = sspecs["m"]
    return state, sspecs


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup))
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    if cfg.factored:
        def updf(p, g, m, vr, vc):
            g = g.astype(jnp.float32) * scale
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            g2 = jnp.square(g) + 1e-30
            if _factorable(p.shape):
                vr = cfg.b2 * vr + (1 - cfg.b2) * g2.mean(-1)
                vc = cfg.b2 * vc + (1 - cfg.b2) * g2.mean(-2)
                vhat = (vr[..., None] * vc[..., None, :]
                        / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
            else:
                vr = cfg.b2 * vr + (1 - cfg.b2) * g2
                vhat = vr
            u = (m32 / b1c) / (jnp.sqrt(vhat / b2c) + cfg.eps)
            w32 = p.astype(jnp.float32)
            w32 = w32 - lr * (u + cfg.weight_decay * w32)
            return w32.astype(p.dtype), m32.astype(jnp.bfloat16), vr, vc

        out = jax.tree.map(updf, params, grads, state["m"], state["vr"],
                           state["vc"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "vr": pick(2), "vc": pick(3),
                         "step": step}, {"grad_norm": gnorm, "lr": lr}

    masters = state.get("master", params)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        w32 = w.astype(jnp.float32)
        w32 = w32 - lr * (u + cfg.weight_decay * w32)
        return w32.astype(p.dtype), m, v, w32

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_state = {
        "m": jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple)),
        "v": jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple)),
        "step": step,
    }
    if "master" in state:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
