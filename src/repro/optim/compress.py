"""Int8 gradient compression for cross-pod all-reduce (optional flag).

Per-tensor symmetric int8 quantization with deterministic-seeded stochastic
rounding.  With SPMD the all-reduce itself is emitted by XLA from the mean
over the batch axis; activating compression reduces the *cross-pod* gradient
traffic 4x by quantize -> (all-reduce in int-as-float) -> dequantize around
the pod-axis reduction (the data-axis reduction stays bf16; intra-pod ICI is
cheap, inter-pod links are the scarce resource — see docs/architecture.md,
"LM-substrate notes").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, rng):
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = g32 / scale
        noise = jax.random.uniform(k, g.shape) - 0.5
        q = jnp.clip(jnp.round(q + noise), -127, 127).astype(jnp.int8)
        out.append((q, scale))
    return treedef, out


def decompress_grads(treedef, compressed, dtype=jnp.float32):
    leaves = [q.astype(jnp.float32) * s for q, s in compressed]
    return jax.tree.unflatten(treedef, [l.astype(dtype) for l in leaves])
