"""Mixture-of-Experts layer: slot-table dispatch + top-k/matching routers.

Dispatch is scatter-based (MegaBlocks-style slot table), not the (T, E, C)
one-hot einsum of GShard — the one-hot dispatch tensor would be ~10 TB for
llama4-maverick's train_4k cell, the slot table is O(E*C*D):

  route   : logits -> (assign, slot, prob) per (token, choice)
  dispatch: scatter tokens into an (E, C, D) expert buffer (XLA -> all-to-all
            when tokens are data-sharded and experts model-sharded)
  expert  : grouped GEMMs over the buffer (E sharded over `model` = EP)
  combine : gather expert outputs back per (token, choice), weight, sum.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.moe import route_matching, route_topk

from .common import AX_DATA, AX_MODEL, ModelConfig, constrain, dense_init, fsdp_spec


def init_moe(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.jdtype
    ks = jax.random.split(key, 7)
    gated = cfg.act in ("swiglu", "geglu")
    params = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "w_in": dense_init(ks[1], (E, D, F), dt),
        "w_out": dense_init(ks[2], (E, F, D), dt),
    }
    if cfg.opt_moe_dispatch:
        # §Perf iteration 2b: FSDP on the *non-contracted* dim of w_in and on
        # the h-matching dim of w_out — the default (data on D) makes BOTH
        # expert matmuls partial-sum over the data axis and all-reduce the
        # (E, C, ·) hidden tensors (2.1 TiB/step for dbrx; see
        # docs/architecture.md, "LM-substrate notes").
        specs = {
            "router": P(None, None),
            "w_in": P(AX_MODEL, None, AX_DATA) if cfg.fsdp
            else P(AX_MODEL, None, None),
            "w_out": P(AX_MODEL, AX_DATA, None) if cfg.fsdp
            else P(AX_MODEL, None, None),
        }
        if gated:
            params["w_gate"] = dense_init(ks[3], (E, D, F), dt)
            specs["w_gate"] = specs["w_in"]
    else:
        specs = {
            "router": P(None, None),
            "w_in": fsdp_spec(P(AX_MODEL, None, None), cfg),
            "w_out": fsdp_spec(P(AX_MODEL, None, None), cfg),
        }
        if gated:
            params["w_gate"] = dense_init(ks[3], (E, D, F), dt)
            specs["w_gate"] = fsdp_spec(P(AX_MODEL, None, None), cfg)
    if cfg.moe_shared_expert:
        # llama4-style always-on shared expert (dense FFN in parallel)
        params["sh_in"] = dense_init(ks[4], (D, F), dt)
        params["sh_out"] = dense_init(ks[5], (F, D), dt)
        specs["sh_in"] = fsdp_spec(P(None, AX_MODEL), cfg)
        specs["sh_out"] = fsdp_spec(P(AX_MODEL, None), cfg)
        if gated:
            params["sh_gate"] = dense_init(ks[6], (D, F), dt)
            specs["sh_gate"] = fsdp_spec(P(None, AX_MODEL), cfg)
    return params, specs


def capacity_for(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * n_tokens * cfg.top_k
                      / cfg.n_experts))
    return max(8, ((c + 7) // 8) * 8)                   # lane-align


def _expert_ffn(params, buf, cfg: ModelConfig):
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * h
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


def moe_ffn_local_dispatch(params, x, cfg: ModelConfig
                           ) -> Tuple[jnp.ndarray, dict]:
    """§Perf variant (opt_moe_dispatch): locality-first expert dispatch.

    The baseline scatters data-sharded tokens straight into a model-sharded
    (E*C, D) buffer; GSPMD lowers that to *full-buffer fp32 all-reduces* per
    layer (960 GiB/layer-step for dbrx train_4k — docs/architecture.md,
    "LM-substrate notes").
    Here every data shard routes and scatters LOCALLY into its own
    (E, C_loc, D) slab (no cross-device traffic), and a single bf16
    all-to-all reshards (shards, E, C_loc, D) from data-sharded shards to
    model-sharded experts.  Routing becomes per-shard (capacity C/shards
    each), which is also the realistic EP semantics at scale.
    """
    from repro.models.common import get_mesh, _LOGICAL
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    mesh = get_mesh()
    shards = 1
    if mesh is not None:
        for ax in _LOGICAL["data"]:
            shards *= mesh.shape[ax]
    if T % shards:
        shards = 1
    T_loc = T // shards
    C_loc = capacity_for(cfg, T_loc)

    xt = x.reshape(shards, T_loc, D)
    xt = constrain(xt, P(AX_DATA, None, None))
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"])
    route = route_matching if cfg.router == "matching" else route_topk
    assign, slot, prob = jax.vmap(lambda l: route(l, k, C_loc))(logits)

    # local scatter into per-shard slabs (leading dim stays data-sharded)
    flat_e = assign.reshape(shards, T_loc * k)
    flat_s = slot.reshape(shards, T_loc * k)
    keep = flat_e >= 0
    slot_id = jnp.where(keep, flat_e * C_loc + flat_s, E * C_loc)
    # instance i corresponds to token i//k: a broadcast, not a gather
    gathered_x = jnp.broadcast_to(xt[:, :, None], (shards, T_loc, k, D)
                                  ).reshape(shards, T_loc * k, D)
    buf = jax.vmap(lambda sid, xg:
                   jnp.zeros((E * C_loc + 1, D), x.dtype).at[sid].set(xg))(
        slot_id, gathered_x)
    buf = buf[:, : E * C_loc].reshape(shards, E, C_loc, D)

    # THE reshard: data-sharded shards -> model-sharded experts (all-to-all).
    # Keep the shards axis through the einsums — reshaping across a sharded
    # dim forces a relayout (measured: +900 GiB collective-permute).
    bufe = constrain(buf.transpose(1, 0, 2, 3),
                     P(AX_MODEL, None, None, None))      # (E, shards, C, D)
    h = jnp.einsum("escd,edf->escf", bufe, params["w_in"])
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("escd,edf->escf", bufe, params["w_gate"])
        h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * h
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    out_e = jnp.einsum("escf,efd->escd", h, params["w_out"])

    # back to data-sharded shards (all-to-all), local gather + combine
    out_s = constrain(out_e.transpose(1, 0, 2, 3),
                      P(AX_DATA, None, None, None)).reshape(
                          shards, E * C_loc, D)
    picked = jax.vmap(lambda o, sid: o[jnp.clip(sid, 0, E * C_loc - 1)])(
        out_s, slot_id)
    picked = jnp.where(keep[..., None], picked, 0.0)
    w = prob.reshape(shards, T_loc * k, 1).astype(x.dtype)
    # combine: instances of one token are contiguous -> reshape-sum (the
    # scatter-add equivalent, but with no scatter and no u32/f32 all-reduce
    # in its backward — §Perf iteration 2c)
    out = (picked * w).reshape(shards, T_loc, k, D).sum(axis=2)

    me = jax.nn.softmax(logits, -1).mean((0, 1))
    onehot = (jax.nn.one_hot(jnp.clip(assign, 0, E - 1), E)
              * (assign >= 0)[..., None]).sum((0, 1, 2)) / max(1, T * k)
    aux = {"lb_loss": E * jnp.sum(me * onehot),
           "drop_rate": 1.0 - keep.sum() / (T * k)}
    out = out.reshape(B, S, D)
    if cfg.moe_shared_expert:
        h = jnp.einsum("bsd,df->bsf", x, params["sh_in"])
        if cfg.act in ("swiglu", "geglu"):
            g = jnp.einsum("bsd,df->bsf", x, params["sh_gate"])
            h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * h
        else:
            h = jax.nn.gelu(h)
        out = out + jnp.einsum("bsf,fd->bsd", h, params["sh_out"])
    return out, aux


def moe_ffn(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (B, S, D), aux metrics (load-balance loss, drops)."""
    if cfg.opt_moe_dispatch:
        return moe_ffn_local_dispatch(params, x, cfg)
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = capacity_for(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    if cfg.router == "matching":
        assign, slot, prob = route_matching(logits, k, C)
    else:
        assign, slot, prob = route_topk(logits, k, C)

    # ---- dispatch: scatter instances into (E*C+1, D); last row = dump ----
    flat_e = assign.reshape(T * k)
    flat_s = slot.reshape(T * k)
    keep = flat_e >= 0
    slot_id = jnp.where(keep, flat_e * C + flat_s, E * C)
    tok_ix = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot_id].set(xt[tok_ix])
    buf = constrain(buf[: E * C].reshape(E, C, D), P(AX_MODEL, None, None))

    out_buf = _expert_ffn(params, buf, cfg)

    # ---- combine: gather back, weight, sum over choices -------------------
    out_flat = out_buf.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(slot_id, 0, E * C - 1)], 0.0)
    w = prob.reshape(T * k, 1).astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_ix].add(gathered * w)

    # load-balance auxiliary loss (Switch style) + drop metric
    me = jax.nn.softmax(logits, -1).mean(0)
    onehot = (jax.nn.one_hot(jnp.clip(assign, 0, E - 1), E)
              * (assign >= 0)[..., None]).sum((0, 1)) / max(1, T * k)
    aux = {
        "lb_loss": E * jnp.sum(me * onehot),
        "drop_rate": 1.0 - keep.sum() / (T * k),
    }
    out = out.reshape(B, S, D)
    if cfg.moe_shared_expert:
        h = jnp.einsum("bsd,df->bsf", x, params["sh_in"])
        if cfg.act in ("swiglu", "geglu"):
            g = jnp.einsum("bsd,df->bsf", x, params["sh_gate"])
            h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * h
        else:
            h = jax.nn.gelu(h)
        out = out + jnp.einsum("bsf,fd->bsd", h, params["sh_out"])
    return out, aux
