"""Dense feed-forward blocks: SwiGLU / GeGLU / squared-ReLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import AX_DATA, AX_MODEL, ModelConfig, dense_init, fsdp_spec


def init_mlp(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    dt = cfg.jdtype
    gated = cfg.act in ("swiglu", "geglu")
    ks = jax.random.split(key, 3)
    params = {"w_in": dense_init(ks[0], (D, F), dt),
              "w_out": dense_init(ks[1], (F, D), dt)}
    specs = {"w_in": fsdp_spec(P(None, AX_MODEL), cfg),
             "w_out": fsdp_spec(P(AX_MODEL, None), cfg)}
    if gated:
        params["w_gate"] = dense_init(ks[2], (D, F), dt)
        specs["w_gate"] = fsdp_spec(P(None, AX_MODEL), cfg)
    return params, specs


def mlp(params, x, cfg: ModelConfig):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        if cfg.act == "swiglu":
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(g) * h
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])
