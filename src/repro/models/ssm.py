"""Mamba-2 (SSD, arXiv:2405.21060) block: chunked training scan + O(1) decode.

The SSD form computes, per head h with scalar decay a_t = exp(dt_t * A_h):

  h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t         (state:  (hd, N))
  y_t = C_t . h_t + D_h * x_t

Training/prefill uses the chunked algorithm: within chunks of Q tokens the
recurrence is expanded into a masked quadratic form (MXU-friendly), states
are passed between chunks with a short ``lax.scan`` — O(S*Q) work, O(S) mem.
Decode is the literal recurrence (one step).  Group count G=1 (B/C shared
across heads), matching Mamba2/Zamba2 publications.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import AX_DATA, AX_MODEL, ModelConfig, constrain, dense_init, fsdp_spec

CHUNK = 256


def init_mamba(key, cfg: ModelConfig):
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, K = cfg.ssm_heads, cfg.ssm_conv
    dt = cfg.jdtype
    ks = jax.random.split(key, 10)
    params = {
        "wz": dense_init(ks[0], (D, di), dt),
        "wx": dense_init(ks[1], (D, di), dt),
        "wB": dense_init(ks[2], (D, N), dt),
        "wC": dense_init(ks[3], (D, N), dt),
        "wdt": dense_init(ks[4], (D, H), dt),
        "conv_x": dense_init(ks[5], (K, di), dt),
        "conv_B": dense_init(ks[6], (K, N), dt),
        "conv_C": dense_init(ks[7], (K, N), dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "Dp": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out": dense_init(ks[8], (di, D), dt),
    }
    specs = {
        "wz": fsdp_spec(P(None, AX_MODEL), cfg),
        "wx": fsdp_spec(P(None, AX_MODEL), cfg),
        "wB": P(None, None), "wC": P(None, None),
        "wdt": P(None, AX_MODEL),
        "conv_x": P(None, AX_MODEL), "conv_B": P(None, None),
        "conv_C": P(None, None),
        "A_log": P(AX_MODEL), "Dp": P(AX_MODEL), "dt_bias": P(AX_MODEL),
        "norm": P(AX_MODEL),
        "out": fsdp_spec(P(AX_MODEL, None), cfg),
    }
    return params, specs


def _causal_conv(x, w):
    """x: (B, S, C); w: (K, C) depthwise causal convolution."""
    K = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pads[:, i: i + x.shape[1]] * w[i]
    return out


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * (1.0 + scale)).astype(y.dtype)


def mamba_forward(params, x, cfg: ModelConfig, h0=None):
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D), final state (B,H,hd,N)."""
    B, S, D = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H, hd = cfg.ssm_heads, cfg.ssm_headdim
    Q = min(CHUNK, S)
    assert S % Q == 0
    nc = S // Q

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xs = _causal_conv(jnp.einsum("bsd,de->bse", x, params["wx"]),
                      params["conv_x"])
    Bc = _causal_conv(jnp.einsum("bsd,dn->bsn", x, params["wB"]),
                      params["conv_B"])
    Cc = _causal_conv(jnp.einsum("bsd,dn->bsn", x, params["wC"]),
                      params["conv_C"])
    xs, Bc, Cc = jax.nn.silu(xs), jax.nn.silu(Bc), jax.nn.silu(Cc)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"]).astype(jnp.float32)
        + params["dt_bias"])                                   # (B,S,H)
    A = -jnp.exp(params["A_log"])                              # (H,)

    xh = xs.reshape(B, nc, Q, H, hd)
    xh = constrain(xh, P(AX_DATA, None, None, AX_MODEL, None))
    Bh = Bc.reshape(B, nc, Q, N)
    Ch = Cc.reshape(B, nc, Q, N)
    dth = dt.reshape(B, nc, Q, H)
    dA = dth * A                                               # (B,nc,Q,H) <0
    seg = jnp.cumsum(dA, axis=2)                               # within-chunk

    # ---- intra-chunk (quadratic, causal-masked) ---------------------------
    decay = jnp.exp(seg[:, :, :, None, :] - seg[:, :, None, :, :])  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcqn,bctn->bcqt", Ch, Bh)                 # (B,nc,Q,Q)
    att = cb[..., None] * decay * dth[:, :, None, :, :]        # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqth,bcthp->bcqhp", att.astype(x.dtype), xh)

    # ---- chunk states + inter-chunk scan ----------------------------------
    chunk_decay = jnp.exp(seg[:, :, -1:, :] - seg)             # (B,nc,Q,H)
    states = jnp.einsum("bcth,bctn,bcthp->bchpn",
                        (chunk_decay * dth).astype(x.dtype), Bh.astype(x.dtype), xh)
    total = jnp.exp(seg[:, :, -1, :])                          # (B,nc,H)

    def chunk_step(h, inp):
        st, tot = inp                                          # (B,H,hd,N),(B,H)
        h_new = h * tot[..., None, None].astype(h.dtype) + st
        return h_new, h                                        # emit h_{c-1}

    if h0 is None:
        h0 = jnp.zeros((B, H, hd, N), x.dtype)
    h_last, h_prevs = jax.lax.scan(
        chunk_step, h0,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,hd,N)

    inter_decay = jnp.exp(seg)                                 # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Ch.astype(x.dtype), h_prevs) \
        * inter_decay[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(B, S, di) \
        + xs * params["Dp"].repeat(hd)[None, None, :].astype(x.dtype)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out"]), h_last


def init_ssm_cache(cfg: ModelConfig, n_layers: int, batch: int, dtype=None):
    dtype = dtype or cfg.jdtype
    H, hd, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    K, di = cfg.ssm_conv, cfg.d_inner
    return {
        "h": jnp.zeros((n_layers, batch, H, hd, N), dtype),
        "conv": jnp.zeros((n_layers, batch, K - 1, di + 2 * cfg.ssm_state),
                          dtype),
    }


def ssm_cache_specs(cfg: ModelConfig):
    return {"h": P(None, AX_DATA, AX_MODEL, None, None),
            "conv": P(None, AX_DATA, None, None)}


def mamba_decode_step(params, x, h, conv_state, cfg: ModelConfig):
    """One-token recurrence. x: (B,1,D); h: (B,H,hd,N); conv: (B,K-1,di+2N)."""
    B = x.shape[0]
    di, N = cfg.d_inner, cfg.ssm_state
    H, hd = cfg.ssm_heads, cfg.ssm_headdim
    z = jnp.einsum("bsd,de->bse", x, params["wz"])[:, 0]
    xBC = jnp.concatenate([
        jnp.einsum("bsd,de->bse", x, params["wx"]),
        jnp.einsum("bsd,dn->bsn", x, params["wB"]),
        jnp.einsum("bsd,dn->bsn", x, params["wC"])], -1)[:, 0]  # (B,di+2N)
    hist = jnp.concatenate([conv_state, xBC[:, None]], 1)       # (B,K,·)
    w = jnp.concatenate([params["conv_x"], params["conv_B"],
                         params["conv_C"]], 1)                  # (K, di+2N)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w)
    conv_state = hist[:, 1:]
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[:, :di].reshape(B, H, hd)
    Bc = conv_out[:, di:di + N]
    Cc = conv_out[:, di + N:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"])[:, 0].astype(jnp.float32)
        + params["dt_bias"])                                    # (B,H)
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * A)                                        # (B,H)
    h = h * da[..., None, None].astype(h.dtype) + jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(x.dtype), xs, Bc)
    y = jnp.einsum("bhpn,bn->bhp", h, Cc) + xs * params["Dp"][None, :, None].astype(x.dtype)
    y = _gated_norm(y.reshape(B, di), z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out"])[:, None]
    return out, h, conv_state
