"""GQA attention: full / sliding-window / chunked / prefix masks, KV-cache
decode, cross-attention, and a memory-safe blockwise (flash-style) path.

Shapes: x (B, S, D); q (B, S, H, hd); k/v (B, S, KV, hd); GQA groups H//KV.
Long sequences use ``blockwise_attn`` — an online-softmax scan over KV blocks
(the XLA-level equivalent of FlashAttention) so the S x S score matrix is
never materialized; the Pallas flash kernel (kernels/flash_attention) is the
TPU hot path with identical semantics, selected with ``attn_impl='pallas'``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (AX_DATA, AX_MODEL, ModelConfig, constrain, dense_init,
                     fsdp_spec, rope)

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig, *, cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    params = {
        "wq": dense_init(ks[0], (D, H, hd), dt),
        "wk": dense_init(ks[1], (D, KV, hd), dt),
        "wv": dense_init(ks[2], (D, KV, hd), dt),
        "wo": dense_init(ks[3], (H, hd, D), dt),
    }
    specs = {
        "wq": fsdp_spec(P(None, AX_MODEL, None), cfg),
        "wk": fsdp_spec(P(None, AX_MODEL, None), cfg),
        "wv": fsdp_spec(P(None, AX_MODEL, None), cfg),
        "wo": fsdp_spec(P(AX_MODEL, None, None), cfg),
    }
    return params, specs


def _mask_fn(kind: str, window: int, prefix_len: int):
    """Returns mask(qpos, kpos) -> bool (True = attend)."""
    def mask(qpos, kpos):
        causal = kpos[None, :] <= qpos[:, None]
        if kind == "bidir":
            return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
        if kind == "causal":
            return causal
        if kind == "swa":
            return causal & (qpos[:, None] - kpos[None, :] < window)
        if kind == "chunked":
            return causal & (qpos[:, None] // window == kpos[None, :] // window)
        if kind == "prefix":
            bidir = (qpos[:, None] < prefix_len) & (kpos[None, :] < prefix_len)
            return causal | bidir
        raise ValueError(kind)
    return mask


def _plain_attn(q, k, v, qpos, kpos, mask_kind, window, prefix_len):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores *= hd ** -0.5
    m = _mask_fn(mask_kind, window, prefix_len)(qpos, kpos)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


def hflat_blockwise_attn(q, k, v, qpos, kpos, mask_kind, window, prefix_len,
                         q_block: int = 1024, kv_block: int = 1024):
    """§Perf variant: H-flat GQA — KV heads broadcast to H inside the score
    einsums so every tensor carries a single head axis that shards H-over-
    model (H=48 splits 16 ways; the grouped (KV=8, G=6) layout cannot, and
    GSPMD falls back to 'involuntary full rematerialization' + fp32 score
    all-gathers — see docs/architecture.md, "LM-substrate notes")."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Skv = k.shape[1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, Skv)
    nq, nk = S // q_block, Skv // kv_block
    mask = _mask_fn(mask_kind, window, prefix_len)
    scale = hd ** -0.5
    head_spec = P(AX_DATA, AX_MODEL, None, None)

    qh = constrain(q.transpose(0, 2, 1, 3), head_spec)      # (B,H,S,hd)
    # broadcast KV->H as a view; XLA fuses it into the dots
    kh = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, Skv, hd)).reshape(B, H, Skv, hd)
    vh = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KV, G, Skv, hd)).reshape(B, H, Skv, hd)
    kh = constrain(kh, head_spec)
    vh = constrain(vh, head_spec)
    qb = qh.reshape(B, H, nq, q_block, hd)
    kb = kh.reshape(B, H, nk, kv_block, hd)
    vb = vh.reshape(B, H, nk, kv_block, hd)
    qp = qpos.reshape(nq, q_block)
    kp = kpos.reshape(nk, kv_block)

    def q_step(_, qi):
        qblk, qpb = qi                                      # (B,H,q,hd),(q,)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk, vblk, kpb = ki
            s = jnp.einsum("bhqd,bhtd->bhqt", qblk, kblk)
            s = constrain((s * scale).astype(jnp.float32), head_spec)
            mm = mask(qpb, kpb)[None, None]
            s = jnp.where(mm, s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bhqt,bhtd->bhqd", p.astype(qblk.dtype), vblk)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), qblk.dtype)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4), kp))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None].astype(acc.dtype)
        return None, out                                    # (B,H,q,hd)

    _, outs = jax.lax.scan(q_step, None,
                           (qb.transpose(2, 0, 1, 3, 4), qp))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return out.transpose(0, 2, 1, 3)


def blockwise_attn(q, k, v, qpos, kpos, mask_kind, window, prefix_len,
                   q_block: int = 1024, kv_block: int = 1024):
    """Online-softmax attention, O(S*B) memory: scan over KV blocks per Q block."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Skv = k.shape[1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, Skv)
    assert S % q_block == 0 and Skv % kv_block == 0
    nq, nk = S // q_block, Skv // kv_block
    mask = _mask_fn(mask_kind, window, prefix_len)
    scale = hd ** -0.5

    qg = q.reshape(B, nq, q_block, KV, G, hd)
    qp = qpos.reshape(nq, q_block)
    kb = k.reshape(B, nk, kv_block, KV, hd)
    vb = v.reshape(B, nk, kv_block, KV, hd)
    kp = kpos.reshape(nk, kv_block)

    def q_step(_, qi):
        qblk, qpb = qi                                  # (B,q,KV,G,hd),(q,)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk, vblk, kpb = ki
            s = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk)
            s = (s * scale).astype(jnp.float32)
            mm = mask(qpb, kpb)[None, None, None]
            s = jnp.where(mm, s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(qblk.dtype), vblk)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), qblk.dtype)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), kp))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None].astype(acc.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)       # (B,q,KV,G,hd)

    _, outs = jax.lax.scan(q_step, None,
                           (qg.transpose(1, 0, 2, 3, 4, 5), qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out


def attention(params, x, pos, cfg: ModelConfig, *, mask_kind: str,
              kv_x: Optional[jnp.ndarray] = None,
              kv_pos: Optional[jnp.ndarray] = None,
              prefix_len: int = 0):
    """Full-sequence attention (training / prefill).

    ``kv_x`` switches to cross-attention (keys/values from encoder output).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if kv_x is None:
        q, k = rope(q, k, pos, cfg.rope_theta)
        kpos = pos
    else:
        kpos = kv_pos
        mask_kind = "bidir"
    q = constrain(q, P(AX_DATA, None, AX_MODEL, None))
    use_pallas = (cfg.attn_impl == "pallas" and kv_x is None
                  and mask_kind in ("causal", "bidir"))
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=(mask_kind == "causal"))
    elif cfg.opt_attn_layout and kv_x is None:
        out = hflat_blockwise_attn(q, k, v, pos, kpos, mask_kind, cfg.window,
                                   prefix_len)
    elif S > 2048 or k.shape[1] > 2048:
        out = blockwise_attn(q, k, v, pos, kpos, mask_kind, cfg.window,
                             prefix_len)
    else:
        out = _plain_attn(q, k, v, pos, kpos, mask_kind, cfg.window,
                          prefix_len)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  dtype=None):
    """Ring-buffer KV cache. For swa/chunked archs max_len = window size.

    ``opt_kv_quant`` (§Perf): int8 storage + per-(pos, head) scales — halves
    the decode HBM traffic, which dominates every decode cell's roofline.
    """
    dtype = dtype or cfg.jdtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    cache_len = min(max_len, cfg.window) if cfg.attn in ("swa", "chunked") \
        else max_len
    store = jnp.int8 if cfg.opt_kv_quant else dtype
    cache = {
        "k": jnp.zeros((n_layers, batch, cache_len, KV, hd), store),
        "v": jnp.zeros((n_layers, batch, cache_len, KV, hd), store),
        "idx": jnp.full((cache_len,), jnp.int32(-1)),   # absolute positions
    }
    if cfg.opt_kv_quant:
        cache["k_scale"] = jnp.zeros((n_layers, batch, cache_len, KV),
                                     jnp.bfloat16)
        cache["v_scale"] = jnp.zeros((n_layers, batch, cache_len, KV),
                                     jnp.bfloat16)
    return cache


def cache_specs(cfg: ModelConfig, shard_seq: bool):
    """KV cache sharding: batch over data; seq over model for big caches
    (split-KV decode), else heads over model when they divide."""
    if shard_seq:
        kv = P(None, AX_DATA, AX_MODEL, None, None)
        sc = P(None, AX_DATA, AX_MODEL, None)
    else:
        kv = P(None, AX_DATA, None, AX_MODEL, None)
        sc = P(None, AX_DATA, None, AX_MODEL)
    specs = {"k": kv, "v": kv, "idx": P(None)}
    if cfg.opt_kv_quant:
        specs["k_scale"] = sc
        specs["v_scale"] = sc
    return specs


def decode_attention(params, x, cache_k, cache_v, cache_idx, pos,
                     cfg: ModelConfig, *, kv_x=None, kv_pos=None,
                     k_scale=None, v_scale=None):
    """One-token attention against the cache (already containing this token's
    k/v written by the caller via ``update_cache``)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])     # (B,1,H,hd)
    if k_scale is not None:                              # int8 cache dequant
        cache_k = cache_k.astype(cfg.jdtype) * k_scale[..., None]
        cache_v = cache_v.astype(cfg.jdtype) * v_scale[..., None]
    if kv_x is None:
        posv = jnp.full((B, 1), pos, jnp.int32)
        q, _ = rope(q, q, posv, cfg.rope_theta)          # rotate q only
        k, v = cache_k, cache_v                          # (B,Sc,KV,hd)
        valid = (cache_idx >= 0) & (cache_idx <= pos)
        if cfg.attn == "swa":
            valid &= pos - cache_idx < cfg.window
        elif cfg.attn == "chunked":
            valid &= cache_idx // cfg.window == pos // cfg.window
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
        valid = jnp.ones((k.shape[1],), bool)
    B, S, H, hd = q.shape[0], k.shape[1], q.shape[2], q.shape[3]
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k).astype(jnp.float32) * hd ** -0.5
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v).reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def update_cache(params, x, cache_k, cache_v, cache_idx, pos,
                 cfg: ModelConfig, k_scale=None, v_scale=None):
    """Write this token's k/v into the ring buffer; returns updated cache."""
    B = x.shape[0]
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])     # (B,1,KV,hd)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    posv = jnp.full((B, 1), pos, jnp.int32)
    _, k = rope(k, k, posv, cfg.rope_theta)
    slot = pos % cache_k.shape[1]
    if k_scale is not None:                              # int8 quantization
        ks = jnp.max(jnp.abs(k), axis=-1) / 127.0        # (B,1,KV)
        vs = jnp.max(jnp.abs(v), axis=-1) / 127.0
        k = jnp.clip(jnp.round(k / jnp.maximum(ks[..., None], 1e-8)),
                     -127, 127).astype(jnp.int8)
        v = jnp.clip(jnp.round(v / jnp.maximum(vs[..., None], 1e-8)),
                     -127, 127).astype(jnp.int8)
        k_scale = jax.lax.dynamic_update_slice(
            k_scale, ks.astype(k_scale.dtype), (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(
            v_scale, vs.astype(v_scale.dtype), (0, slot, 0))
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    cache_idx = jax.lax.dynamic_update_slice(
        cache_idx, jnp.asarray(pos, jnp.int32)[None], (slot,))
    if k_scale is not None:
        return cache_k, cache_v, cache_idx, k_scale, v_scale
    return cache_k, cache_v, cache_idx
