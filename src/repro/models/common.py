"""Shared building blocks for the model zoo.

Parameters are plain nested dicts of jnp arrays; every ``init_*`` function
returns ``(params, specs)`` where ``specs`` mirrors the params tree with
``jax.sharding.PartitionSpec`` leaves.  Mesh axis conventions:

* ``data`` (+ ``pod`` when present)  — batch / FSDP axis (name: AX_DATA)
* ``model``                          — tensor-parallel axis (heads, d_ff, experts, vocab)

``fsdp=True`` additionally shards the *first non-model* weight axis over the
data axis (GSPMD re-gathers per scan step), which is what lets the 132B-400B
archs fit 256 chips.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

AX_MODEL = "model"
AX_DATA = "data"          # resolved to ("pod","data") on multi-pod meshes

# --- logical -> physical axis resolution -----------------------------------
# Specs are written with logical names ("data", "model"); the launch layer
# registers the active mesh + mapping (multi-pod maps "data" -> (pod, data)).
_MESH = None
_LOGICAL = {"data": ("data",), "model": ("model",)}


def set_mesh(mesh, logical: Optional[Dict[str, Tuple[str, ...]]] = None):
    global _MESH, _LOGICAL
    _MESH = mesh
    if logical is not None:
        _LOGICAL = dict(logical)


def get_mesh():
    return _MESH


def resolve_spec(spec: P) -> P:
    """Map logical axis names in a PartitionSpec to physical mesh axes."""
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, str):
            phys = _LOGICAL.get(part, (part,))
            out.append(phys[0] if len(phys) == 1 else phys)
        else:  # tuple of logical names
            phys: Tuple[str, ...] = ()
            for q in part:
                phys += _LOGICAL.get(q, (q,))
            out.append(phys)
    return P(*out)


def _axes_size(mesh, part) -> int:
    names = (part,) if isinstance(part, str) else tuple(part)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Make a resolved spec legal for `shape` on `mesh`.

    Mesh axes on non-divisible dims are removed and, when possible, relocated
    to another unsharded dim that divides — e.g. deepseek's 56 heads cannot
    split 16 ways, so the model axis moves to head_dim (128); seamless's
    256206-row vocab moves the model axis to d_model.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts = parts[: len(shape)]
    homeless = []
    for i, part in enumerate(parts):
        if part is None:
            continue
        if shape[i] % _axes_size(mesh, part) != 0:
            # try dropping individual axes before evicting all of them
            names = (part,) if isinstance(part, str) else list(part)
            keep = []
            for a in names:
                trial = keep + [a]
                if shape[i] % _axes_size(mesh, tuple(trial)) == 0:
                    keep = trial
                else:
                    homeless.append(a)
            parts[i] = None if not keep else (
                keep[0] if len(keep) == 1 else tuple(keep))
    for a in homeless:
        used = set()
        for p in parts:
            if p is not None:
                used.update((p,) if isinstance(p, str) else p)
        if a in used:
            continue
        for i, part in enumerate(parts):
            if part is None and shape[i] % mesh.shape[a] == 0 and shape[i] > 1:
                parts[i] = a
                break
    return P(*parts)


def named_sharding(mesh, spec: P, shape):
    return jax.sharding.NamedSharding(
        mesh, sanitize_spec(resolve_spec(spec), shape, mesh))


def constrain(x, spec: P):
    """with_sharding_constraint against the registered mesh (no-op outside)."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(_MESH, spec, x.shape))


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "swiglu"            # swiglu | geglu | relu2 | gelu
    attn: str = "full"             # full | swa | chunked
    window: int = 4096             # swa window / chunk size
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    router: str = "topk"           # topk | matching  (paper technique)
    capacity_factor: float = 1.25
    moe_every: int = 1             # MoE layer every k-th block (1 = all)
    moe_shared_expert: bool = False  # always-on shared expert (llama4)
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # hybrid: one shared attention block every `shared_every` mamba blocks
    shared_every: int = 0
    # encoder-decoder
    enc_layers: int = 0
    # frontends (stubbed per spec: input_specs provides embeddings)
    frontend: str = ""             # "" | "audio" | "vision"
    frontend_len: int = 256        # patches / frames prepended
    # numerics / partitioning
    dtype: str = "bfloat16"
    fsdp: bool = False
    remat: bool = True
    attn_impl: str = "xla"         # xla | pallas (flash kernel)
    # --- beyond-baseline performance knobs (docs/architecture.md) ---------
    # H-flat attention layout: fold GQA groups into the head axis so score
    # tensors shard cleanly H-over-model (fixes involuntary resharding).
    opt_attn_layout: bool = False
    # locality-first MoE dispatch: per-data-shard routing + local scatter,
    # single all-to-all reshard to expert-parallel layout (replaces the
    # full-buffer all-reduce pattern GSPMD derives from global scatters).
    opt_moe_dispatch: bool = False
    # int8 KV cache with per-(layer,head) scales: halves decode HBM traffic.
    opt_kv_quant: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dec_layers(self) -> int:
        return self.n_layers

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def params_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * hd * (H + 2 * KV) + H * hd * D
        if self.act in ("swiglu", "geglu"):
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        per = attn + 2 * D
        if self.family == "moe":
            moe_l = self.n_experts * mlp + D * self.n_experts
            n_moe = L // self.moe_every
            per_total = L * per + n_moe * moe_l + (L - n_moe) * mlp
        elif self.family == "ssm":
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            per = (D * (2 * di + 2 * N + Hs)      # in_proj (z,x,B,C,dt)
                   + di * D + 2 * D)              # out_proj + norms
            per_total = L * per
        elif self.family == "hybrid":
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            mamba = D * (2 * di + 2 * N + Hs) + di * D + 2 * D
            n_shared = 1 if self.shared_every else 0
            per_total = L * mamba + n_shared * (attn + mlp + 2 * D)
        else:
            per_total = L * (per + mlp)
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.enc_layers:
            per_total += self.enc_layers * (attn + mlp + 2 * D)
            per_total += self.n_layers * attn     # cross attention
        return per_total + emb


# ---------------------------------------------------------------------------
# initializers / specs
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis: int = 0) -> jnp.ndarray:
    fan_in = np.prod([shape[i] for i in ([in_axis] if isinstance(in_axis, int)
                                         else in_axis)])
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def fsdp_spec(spec: P, cfg: ModelConfig) -> P:
    """Shard the first unsharded axis over data when FSDP is on."""
    if not cfg.fsdp:
        return spec
    parts = list(spec)
    for i, p in enumerate(parts):
        if p is None:
            parts[i] = AX_DATA
            return P(*parts)
    return spec


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def activate(act: str, h, g=None):
    if act == "swiglu":
        return jax.nn.silu(g) * h
    if act == "geglu":
        return jax.nn.gelu(g) * h
    if act == "relu2":                       # Nemotron-4 squared ReLU
        return jnp.square(jax.nn.relu(h))
    if act == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(act)


def rope(q, k, pos, theta: float):
    """Rotary embeddings; q,k: (..., S, H, hd), pos: (..., S) int32."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = pos[..., :, None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        xd = x.dtype
        x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
        return jnp.concatenate([x1 * cos - x2 * sin,
                                x2 * cos + x1 * sin], -1).astype(xd)

    return rot(q), rot(k)


def tree_size(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
