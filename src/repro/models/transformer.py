"""Model assembly: decoder-only LM, MoE LM, SSM, hybrid, enc-dec, VLM.

Layer stacks are ``lax.scan`` over parameters stacked on a leading L axis —
this keeps the 512-device HLO compact (one block body) and is what remat
wants.  ``build_model(cfg)`` returns a ``Model`` with:

  init(rng)                  -> (params, specs)
  forward(params, batch)     -> logits                   (train / prefill)
  init_cache(batch, max_len) -> (cache, cache_specs)
  decode_step(params, cache, tokens, pos) -> (logits, cache)

``batch`` is a dict: {"tokens": (B,S) int32} plus, for stub frontends,
{"frontend": (B, F, D) embeddings} and for enc-dec {"enc_frames": (B,Se,D)}.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as att
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (AX_DATA, AX_MODEL, ModelConfig, constrain, dense_init,
                     fsdp_spec, rms_norm)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str, cross: bool = False):
    ks = jax.random.split(key, 4)
    params: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                              "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    specs: Dict[str, Any] = {"ln1": P(None), "ln2": P(None)}
    if kind == "mamba":
        params["mix"], specs["mix"] = ssm_mod.init_mamba(ks[0], cfg)
        del params["ln2"], specs["ln2"]
        return params, specs
    params["attn"], specs["attn"] = att.init_attn(ks[0], cfg)
    if cross:
        params["xattn"], specs["xattn"] = att.init_attn(ks[1], cfg, cross=True)
        params["lnx"] = jnp.zeros((cfg.d_model,), jnp.float32)
        specs["lnx"] = P(None)
    if kind == "moe":
        params["ffn"], specs["ffn"] = moe_mod.init_moe(ks[2], cfg)
    else:
        params["ffn"], specs["ffn"] = mlp_mod.init_mlp(ks[2], cfg)
    return params, specs


def block_fwd(params, x, pos, cfg: ModelConfig, kind: str, mask_kind: str,
              enc_out=None, enc_pos=None, prefix_len: int = 0):
    aux = {}
    if cfg.fsdp and x.shape[1] > 1:
        # Megatron-style sequence parallelism: the residual stream (and hence
        # the per-layer remat stash) is seq-sharded over the model axis;
        # attention/MLP re-gather. 96-layer 340B stash: 14.5 GB -> 0.9 GB/dev.
        x = constrain(x, P(AX_DATA, AX_MODEL, None))
    if kind == "mamba":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, _ = ssm_mod.mamba_forward(params["mix"], h, cfg)
        return x + y, aux
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    x = x + att.attention(params["attn"], h, pos, cfg, mask_kind=mask_kind,
                          prefix_len=prefix_len)
    if enc_out is not None:
        h = rms_norm(x, params["lnx"], cfg.norm_eps)
        x = x + att.attention(params["xattn"], h, pos, cfg,
                              mask_kind="bidir", kv_x=enc_out, kv_pos=enc_pos)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_mod.moe_ffn(params["ffn"], h, cfg)
    else:
        y = mlp_mod.mlp(params["ffn"], h, cfg)
    return x + y, aux


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    params = {"tok": dense_init(ks[0], (cfg.vocab, cfg.d_model), dt),
              "ln_f": jnp.zeros((cfg.d_model,), jnp.float32)}
    specs = {"tok": P(AX_MODEL, None), "ln_f": P(None)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dt)
        specs["unembed"] = fsdp_spec(P(None, AX_MODEL), cfg)
    return params, specs


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["tok"], tokens, axis=0)
    return constrain(x, P(AX_DATA, None, None))


def vocab_padded(cfg: ModelConfig) -> int:
    """Vocab rounded to a lane multiple so logits shard over the model axis
    (exact-vocab logits for e.g. seamless's 256206 would be forced to
    replicate: 31 GiB/device at prefill_32k). Params keep the exact vocab."""
    return -(-cfg.vocab // 128) * 128


def lm_head(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    V, Vp = cfg.vocab, vocab_padded(cfg)
    if cfg.tie_embeddings:
        w = params["tok"]
        if Vp != V:
            w = jnp.pad(w, ((0, Vp - V), (0, 0)))
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        w = params["unembed"]
        if Vp != V:
            w = jnp.pad(w, ((0, 0), (0, Vp - V)))
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    if Vp != V:
        pad = jnp.arange(Vp, dtype=jnp.int32) >= V
        logits = jnp.where(pad[None, None, :], jnp.asarray(-1e30, x.dtype),
                           logits)
    return constrain(logits, P(AX_DATA, None, AX_MODEL))


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init -------------------------------------------------
    def init(self, rng) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        params, specs = {}, {}
        params["embed"], specs["embed"] = init_embed(keys[0], cfg)

        kind = self._block_kind()
        cross = cfg.enc_layers > 0

        def stack_init(key, n, kind, cross=False):
            ks = jax.random.split(key, n)
            ps, sp = [], None
            for i in range(n):
                p, sp = init_block(ks[i], cfg, kind, cross)
                ps.append(p)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
            sspec = jax.tree.map(lambda s: P(None, *s), sp,
                                 is_leaf=lambda s: isinstance(s, P))
            return stacked, sspec

        params["layers"], specs["layers"] = stack_init(
            keys[1], cfg.n_layers, kind, cross)
        if cfg.family == "hybrid" and cfg.shared_every:
            params["shared"], specs["shared"] = init_block(
                keys[2], cfg, "attn")
        if cfg.enc_layers:
            params["enc"], specs["enc"] = stack_init(
                keys[3], cfg.enc_layers, "attn")
        if cfg.frontend == "vision":
            # projection of (stub) patch embeddings into d_model
            params["vproj"] = dense_init(keys[4], (cfg.d_model, cfg.d_model),
                                         cfg.jdtype)
            specs["vproj"] = P(None, None)
        return params, specs

    def _block_kind(self) -> str:
        if self.cfg.family == "moe":
            return "moe"
        if self.cfg.family in ("ssm", "hybrid"):
            return "mamba"
        return "attn"

    def _mask_kind(self) -> str:
        return {"full": "causal", "swa": "swa", "chunked": "chunked"}[
            self.cfg.attn]

    # ---------------- stacks ----------------------------------------------
    def _run_stack(self, layer_params, x, pos, kind, mask_kind,
                   shared=None, enc_out=None, enc_pos=None, prefix_len=0):
        cfg = self.cfg

        def body(carry, lp_idx):
            x = carry
            lp, idx = lp_idx
            x, aux = block_fwd(lp, x, pos, cfg, kind, mask_kind,
                               enc_out=enc_out, enc_pos=enc_pos,
                               prefix_len=prefix_len)
            if shared is not None and cfg.shared_every:
                def with_shared(x):
                    y, _ = block_fwd(shared, x, pos, cfg, "attn", "swa")
                    return y
                x = jax.lax.cond(
                    (idx % cfg.shared_every) == cfg.shared_every - 1,
                    with_shared, lambda x: x, x)
            lb = aux.get("lb_loss", jnp.float32(0.0))
            return x, lb

        body_fn = jax.checkpoint(body) if cfg.remat else body
        n = jax.tree.leaves(layer_params)[0].shape[0]
        x, lbs = jax.lax.scan(body_fn, x, (layer_params,
                                           jnp.arange(n, dtype=jnp.int32)))
        return x, jnp.sum(lbs)

    # ---------------- forward (train / prefill) ---------------------------
    def forward(self, params, batch, last_only: bool = False
                ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens, cfg)
        prefix_len = 0
        if cfg.frontend == "vision":
            v = jnp.einsum("bfd,de->bfe", batch["frontend"].astype(cfg.jdtype),
                           params["vproj"])
            x = jnp.concatenate([v, x], axis=1)
            prefix_len = cfg.frontend_len
            S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)

        enc_out = enc_pos = None
        if cfg.enc_layers:
            frames = batch["enc_frames"].astype(cfg.jdtype)
            enc_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
            enc_out, _ = self._run_stack(params["enc"], frames, enc_pos,
                                         "attn", "bidir")

        mask_kind = "prefix" if prefix_len else self._mask_kind()
        x, lb = self._run_stack(
            params["layers"], x, pos, self._block_kind(), mask_kind,
            shared=params.get("shared"), enc_out=enc_out, enc_pos=enc_pos,
            prefix_len=prefix_len)
        if last_only:
            # serving prefill needs only the next-token logits; computing the
            # full (B, S, V) projection would dominate peak memory.
            return lm_head(params["embed"], x[:, -1:], cfg), {"lb_loss": lb}
        logits = lm_head(params["embed"], x, cfg)
        if cfg.frontend == "vision":
            logits = logits[:, cfg.frontend_len:]
        return logits, {"lb_loss": lb}

    # ---------------- decode ----------------------------------------------
    def init_cache(self, batch_size: int, max_len: int,
                   enc_len: int = 0) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        cache: Dict[str, Any] = {"pos": jnp.int32(0)}
        cspec: Dict[str, Any] = {"pos": P()}
        big = max_len > 8192
        if cfg.family in ("ssm", "hybrid"):
            c = ssm_mod.init_ssm_cache(cfg, cfg.n_layers, batch_size)
            s = ssm_mod.ssm_cache_specs(cfg)
            cache.update(c)
            cspec.update(s)
            if cfg.family == "hybrid" and cfg.shared_every:
                # one cache slice per shared-block INVOCATION: each call sees
                # different layer activations, so caches must not be shared
                n_inv = cfg.n_layers // cfg.shared_every
                kv = att.init_kv_cache(cfg, n_inv, batch_size,
                                       min(max_len, cfg.window))
                ks = att.cache_specs(cfg, shard_seq=False)
                cache["shared_kv"] = kv
                cspec["shared_kv"] = ks
        else:
            kv = att.init_kv_cache(cfg, cfg.n_layers, batch_size, max_len)
            cache.update(kv)
            cspec.update(att.cache_specs(cfg, shard_seq=big))
        if cfg.enc_layers:
            # cross-attention K/V from the encoder, fixed during decode
            KV, hd = cfg.n_kv_heads, cfg.hd
            cache["xk"] = jnp.zeros((cfg.n_layers, batch_size, enc_len, KV,
                                     hd), cfg.jdtype)
            cache["xv"] = jnp.zeros_like(cache["xk"])
            cspec["xk"] = P(None, AX_DATA, None, AX_MODEL, None)
            cspec["xv"] = cspec["xk"]
        return cache, cspec

    def prefill_encoder(self, params, cache, batch):
        """Enc-dec: run encoder, fill cross-attention K/V cache."""
        cfg = self.cfg
        frames = batch["enc_frames"].astype(cfg.jdtype)
        enc_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        enc_out, _ = self._run_stack(params["enc"], frames, enc_pos, "attn",
                                     "bidir")

        def per_layer(carry, lp):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            return carry, (k, v)

        _, (xk, xv) = jax.lax.scan(per_layer, None, params["layers"])
        cache = dict(cache, xk=xk, xv=xv)
        return cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1); pos: int32 scalar (same position across batch)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_tokens(params["embed"], tokens, cfg)
        kind = self._block_kind()

        if kind == "mamba":
            def body(carry, lp_cache):
                x, shared_kv, layer_i = carry
                lp, h, conv = lp_cache
                hnorm = rms_norm(x, lp["ln1"], cfg.norm_eps)
                y, h, conv = ssm_mod.mamba_decode_step(
                    lp["mix"], hnorm, h, conv, cfg)
                x = x + y
                if cfg.family == "hybrid" and cfg.shared_every:
                    inv = layer_i // cfg.shared_every

                    def with_shared(args):
                        x, kv = args
                        return self._shared_decode(params["shared"], x, kv,
                                                   pos, inv)
                    x, shared_kv = jax.lax.cond(
                        (layer_i % cfg.shared_every) == cfg.shared_every - 1,
                        with_shared, lambda a: a, (x, shared_kv))
                return (x, shared_kv, layer_i + 1), (h, conv)

            shared_kv = cache.get("shared_kv")
            (x, shared_kv, _), (hs, convs) = jax.lax.scan(
                body, (x, shared_kv, jnp.int32(0)),
                (params["layers"], cache["h"], cache["conv"]))
            cache = dict(cache, h=hs, conv=convs, pos=pos + 1)
            if shared_kv is not None:
                cache["shared_kv"] = shared_kv
        else:
            quant = cfg.opt_kv_quant

            def body(x, lp_cache):
                lp_cache = list(lp_cache)
                lp, ck, cv, cidx_l = lp_cache[:4]
                rest = lp_cache[4:]
                ksc = vsc = None
                if quant:
                    ksc, vsc = rest[0], rest[1]
                    rest = rest[2:]
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                if quant:
                    ck, cv, cidx_l, ksc, vsc = att.update_cache(
                        lp["attn"], h, ck, cv, cidx_l, pos, cfg, ksc, vsc)
                else:
                    ck, cv, cidx_l = att.update_cache(lp["attn"], h, ck, cv,
                                                      cidx_l, pos, cfg)
                x = x + att.decode_attention(lp["attn"], h, ck, cv, cidx_l,
                                             pos, cfg, k_scale=ksc,
                                             v_scale=vsc)
                if cfg.enc_layers:
                    xk, xv = rest
                    h = rms_norm(x, lp["lnx"], cfg.norm_eps)
                    x = x + self._cross_decode(lp["xattn"], h, xk, xv)
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                if kind == "moe":
                    y, _ = moe_mod.moe_ffn(lp["ffn"], h, cfg)
                else:
                    y = mlp_mod.mlp(lp["ffn"], h, cfg)
                out = (ck, cv, cidx_l) + ((ksc, vsc) if quant else ())
                return x + y, out

            # per-layer cache index: same idx array per layer, stacked
            cidx = jnp.broadcast_to(cache["idx"],
                                    (cfg.n_layers,) + cache["idx"].shape)
            xs = (params["layers"], cache["k"], cache["v"], cidx)
            if quant:
                xs = xs + (cache["k_scale"], cache["v_scale"])
            if cfg.enc_layers:
                xs = xs + (cache["xk"], cache["xv"])
            x, outs = jax.lax.scan(body, x, xs)
            cache = dict(cache, k=outs[0], v=outs[1], idx=outs[2][0],
                         pos=pos + 1)
            if quant:
                cache["k_scale"], cache["v_scale"] = outs[3], outs[4]

        logits = lm_head(params["embed"], x, cfg)
        return logits, cache

    def _cross_decode(self, p, x, xk, xv):
        cfg = self.cfg
        B = x.shape[0]
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        H, hd = cfg.n_heads, cfg.hd
        KV = xk.shape[2]
        G = H // KV
        qg = q.reshape(B, KV, G, hd)
        s = jnp.einsum("bkgh,btkh->bkgt", qg, xk).astype(jnp.float32)
        s *= hd ** -0.5
        pr = jax.nn.softmax(s, -1).astype(x.dtype)
        out = jnp.einsum("bkgt,btkh->bkgh", pr, xv).reshape(B, 1, H, hd)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    def _shared_decode(self, p, x, kv, pos, inv):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        ck = jax.lax.dynamic_index_in_dim(kv["k"], inv, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(kv["v"], inv, keepdims=False)
        cidx = kv["idx"]                       # positions shared across invs
        ck, cv, cidx = att.update_cache(p["attn"], h, ck, cv, cidx, pos, cfg)
        x = x + att.decode_attention(p["attn"], h, ck, cv, cidx, pos, cfg)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp(p["ffn"], h, cfg)
        kv = dict(kv,
                  k=jax.lax.dynamic_update_index_in_dim(kv["k"], ck, inv, 0),
                  v=jax.lax.dynamic_update_index_in_dim(kv["v"], cv, inv, 0),
                  idx=cidx)
        return x, kv


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
