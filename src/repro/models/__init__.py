from .common import ModelConfig, set_mesh, get_mesh, resolve_spec, constrain
from .transformer import Model, build_model

__all__ = ["ModelConfig", "Model", "build_model", "set_mesh", "get_mesh",
           "resolve_spec", "constrain"]
