"""Pure-jnp oracle for the frontier-expansion kernel.

Semantics (one BFS level of the paper's Alg. 2 / Alg. 4, proposal half):
for every edge e = (c, r):
  active  = bfs[c] == level            (and, WR: bfs[root[c]] >= L0-1)
  propose = active and ( (rmatch[r] >= 0 and bfs[rmatch[r]] == L0-1)
                         or rmatch[r] == -1 )
  out[e]  = c if propose else IINF

The scatter/merge half (min per row) is shared, deterministic jnp in the
matcher; the kernel covers the gather-heavy proposal sweep, which is the
memory-bound hot loop the paper tunes with its MT/CT thread geometry.
"""
from __future__ import annotations

import jax.numpy as jnp

UNVISITED = jnp.int32(1)
IINF = jnp.int32(2**30)


def frontier_expand_ref(ecol, cadj, bfs, root, rmatch, level):
    nc = bfs.shape[0] - 1
    active = bfs[ecol] == level
    if root is not None:
        active &= bfs[root[ecol]] >= UNVISITED
    cm = rmatch[cadj]
    col_unvis = bfs[jnp.clip(cm, 0, nc)] == UNVISITED
    target = active & ((cm >= 0) & col_unvis | (cm == -1))
    return jnp.where(target, ecol, IINF)
