"""Pure-jnp oracles for the frontier-expansion kernels.

Semantics (one BFS level of the paper's Alg. 2 / Alg. 4, proposal half):
for every edge e = (c, r):
  active  = bfs[c] == level            (and, WR: bfs[root[c]] >= L0-1)
  propose = active and ( (rmatch[r] >= 0 and bfs[rmatch[r]] == L0-1)
                         or rmatch[r] == -1 )
  out[e]  = c if propose else IINF

:func:`frontier_expand_ref` is that proposal sweep alone (the legacy kernel
contract); :func:`frontier_expand_fused_ref` composes it with the
deterministic per-row min-merge ("first writer wins" = lowest proposing
column), which is the fused kernel's contract: a ``(nr+1,)`` winner vector
with IINF in every unreached row and in the trailing sentinel slot.
"""
from __future__ import annotations

import jax.numpy as jnp

UNVISITED = jnp.int32(1)
IINF = jnp.int32(2**30)


def frontier_expand_ref(ecol, cadj, bfs, root, rmatch, level):
    nc = bfs.shape[0] - 1
    active = bfs[ecol] == level
    if root is not None:
        active &= bfs[root[ecol]] >= UNVISITED
    cm = rmatch[cadj]
    col_unvis = bfs[jnp.clip(cm, 0, nc)] == UNVISITED
    target = active & ((cm >= 0) & col_unvis | (cm == -1))
    return jnp.where(target, ecol, IINF)


def frontier_expand_fused_ref(ecol, cadj, bfs, root, rmatch, level):
    """Proposals + per-row min-merge: the fused kernel's oracle."""
    nr = rmatch.shape[0] - 1
    prop = frontier_expand_ref(ecol, cadj, bfs, root, rmatch, level)
    rows = jnp.where(prop < IINF, cadj, jnp.int32(nr))
    win = jnp.full(nr + 1, IINF, jnp.int32).at[rows].min(prop)
    return win.at[nr].set(IINF)


def frontier_expand_pull_ref(radj, erow, bfs, root, rmatch, level):
    """Pull-kernel oracle: the same min-merge over the row-sorted (CSC)
    edge view — the proposal predicate is per-edge and min is the merge,
    so this is definitionally the fused oracle on permuted arrays."""
    return frontier_expand_fused_ref(radj, erow, bfs, root, rmatch, level)
