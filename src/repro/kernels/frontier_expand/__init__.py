from .ops import frontier_expand
from .ref import frontier_expand_ref

__all__ = ["frontier_expand", "frontier_expand_ref"]
