from .frontier_expand import LANE
from .ops import (frontier_expand, frontier_expand_fused,
                  frontier_expand_pull, resolve_interpret)
from .ref import (frontier_expand_fused_ref, frontier_expand_pull_ref,
                  frontier_expand_ref)

__all__ = ["LANE", "frontier_expand", "frontier_expand_fused",
           "frontier_expand_pull", "frontier_expand_ref",
           "frontier_expand_fused_ref", "frontier_expand_pull_ref",
           "resolve_interpret"]
