"""Pallas TPU kernel: edge-tiled BFS frontier expansion (paper Alg. 2/4).

TPU adaptation of the paper's GPUBFS / GPUBFS-WR CUDA kernels
--------------------------------------------------------------
The CUDA kernel assigns columns to threads (MT: one column per thread,
CT: strided batches per thread) and each thread walks its CSR row segment
through global memory, relying on coalescing across the warp.

On TPU the analogous structure is:

* the *edge list* (``ecol``, ``cadj``) is tiled into VMEM blocks of
  ``block_edges`` lanes — the regular, streaming traffic (HBM -> VMEM), which
  is what the GPU coalesced accesses become;
* the BFS state vectors (``bfs``, ``root``, ``rmatch``) stay VMEM-resident
  across the whole grid (they are O(n) and reused by every tile) and are
  accessed with on-chip dynamic gathers — the GPU's random global-memory
  reads become VMEM gathers with ~20x the bandwidth;
* the paper's MT/CT knob becomes ``block_edges`` (tile granularity): CT's
  coarse-grained strided batches correspond to large tiles (4096 lanes),
  MT's fine-grained one-vertex-per-thread to small tiles (512).

The kernel emits per-edge column proposals (IINF = no proposal); the
deterministic per-row min-merge happens outside (shared with the jnp path),
because scatters with data-dependent indices do not vectorize on the VPU,
whereas the proposal sweep is the dominant O(nnz)-per-level cost.

VMEM budget (defaults): 3 state vectors of (n+1) int32 + 3 edge tiles of
``block_edges`` int32 = 4*(3n + 3*4096) bytes ~= 12n B + 48 KiB; for n = 1M
that is ~12 MiB, inside the 16 MiB v5e VMEM; larger graphs partition the
edges over the mesh (repro.matching.ShardedMatcher) and each shard tiles its
own slice.  (This budget math is also walked through in
docs/architecture.md, "The Pallas frontier kernel".)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UNVISITED = 1          # python ints: safe to close over in kernels
IINF = 2**30


def _kernel_wr(level_ref, ecol_ref, cadj_ref, bfs_ref, root_ref, rmatch_ref,
               out_ref):
    level = level_ref[0]
    ecol = ecol_ref[...]
    cadj = cadj_ref[...]
    bfs = bfs_ref[...]
    nc = bfs.shape[0] - 1
    # frontier check + WR early-exit (Alg. 4 lines 4-7)
    col_level = jnp.take(bfs, ecol, axis=0)
    active = col_level == level
    myroot = jnp.take(root_ref[...], ecol, axis=0)
    active &= jnp.take(bfs, myroot, axis=0) >= UNVISITED
    # row -> matched column lookup (Alg. 4 lines 9-10)
    cm = jnp.take(rmatch_ref[...], cadj, axis=0)
    col_unvis = jnp.take(bfs, jnp.clip(cm, 0, nc), axis=0) == UNVISITED
    target = active & ((cm >= 0) & col_unvis | (cm == -1))
    out_ref[...] = jnp.where(target, ecol, jnp.int32(IINF))


def _kernel_plain(level_ref, ecol_ref, cadj_ref, bfs_ref, rmatch_ref, out_ref):
    level = level_ref[0]
    ecol = ecol_ref[...]
    cadj = cadj_ref[...]
    bfs = bfs_ref[...]
    nc = bfs.shape[0] - 1
    col_level = jnp.take(bfs, ecol, axis=0)
    active = col_level == level
    cm = jnp.take(rmatch_ref[...], cadj, axis=0)
    col_unvis = jnp.take(bfs, jnp.clip(cm, 0, nc), axis=0) == UNVISITED
    target = active & ((cm >= 0) & col_unvis | (cm == -1))
    out_ref[...] = jnp.where(target, ecol, jnp.int32(IINF))


@functools.partial(jax.jit, static_argnames=("block_edges", "interpret"))
def frontier_expand(ecol, cadj, bfs, root, rmatch, level, *,
                    block_edges: int = 4096, interpret: bool = True):
    """Per-edge frontier proposals; ``root=None`` selects the plain kernel."""
    nnz = ecol.shape[0]
    assert nnz % block_edges == 0, (nnz, block_edges)
    grid = (nnz // block_edges,)
    level_arr = jnp.asarray(level, jnp.int32).reshape(1)

    edge_spec = pl.BlockSpec((block_edges,), lambda i: (i,))
    state_spec = pl.BlockSpec(bfs.shape, lambda i: (0,))  # replicated per tile
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    if root is not None:
        return pl.pallas_call(
            _kernel_wr,
            grid=grid,
            in_specs=[scalar_spec, edge_spec, edge_spec, state_spec,
                      pl.BlockSpec(root.shape, lambda i: (0,)),
                      pl.BlockSpec(rmatch.shape, lambda i: (0,))],
            out_specs=edge_spec,
            out_shape=jax.ShapeDtypeStruct((nnz,), jnp.int32),
            interpret=interpret,
        )(level_arr, ecol, cadj, bfs, root, rmatch)
    return pl.pallas_call(
        _kernel_plain,
        grid=grid,
        in_specs=[scalar_spec, edge_spec, edge_spec, state_spec,
                  pl.BlockSpec(rmatch.shape, lambda i: (0,))],
        out_specs=edge_spec,
        out_shape=jax.ShapeDtypeStruct((nnz,), jnp.int32),
        interpret=interpret,
    )(level_arr, ecol, cadj, bfs, rmatch)
