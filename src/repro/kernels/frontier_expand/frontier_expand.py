"""Pallas TPU kernels: edge-tiled BFS frontier expansion (paper Alg. 2/4).

TPU adaptation of the paper's GPUBFS / GPUBFS-WR CUDA kernels
--------------------------------------------------------------
The CUDA kernel assigns columns to threads (MT: one column per thread,
CT: strided batches per thread) and each thread walks its CSR row segment
through global memory, relying on coalescing across the warp.

On TPU the analogous structure is:

* the *edge list* (``ecol``, ``cadj``) is tiled into VMEM blocks of
  ``block_edges`` lanes — the regular, streaming traffic (HBM -> VMEM), which
  is what the GPU coalesced accesses become;
* the BFS state vectors (``bfs``, ``root``, ``rmatch``) stay VMEM-resident
  across the whole grid (they are O(n) and reused by every tile) and are
  accessed with on-chip dynamic gathers — the GPU's random global-memory
  reads become VMEM gathers with ~20x the bandwidth;
* the paper's MT/CT knob becomes ``block_edges`` (tile granularity): CT's
  coarse-grained strided batches correspond to large tiles (4096 lanes),
  MT's fine-grained one-vertex-per-thread to small tiles (512).

Three kernel families share one proposal formula (:func:`_proposals`):

* :func:`frontier_expand` (legacy) emits the per-edge column proposals
  (IINF = no proposal) as an (nnz,) array; the deterministic per-row
  min-merge then runs as a separate XLA scatter outside the kernel.
* :func:`frontier_expand_fused` keeps a ``(nr+1,)`` winner accumulator
  resident in VMEM across the whole edge-tile grid (the output block maps to
  the same slot for every grid step, so sequential grid revision carries it)
  and min-merges each tile's proposals into it *inside* the kernel.  The
  (nnz,) proposal array and its HBM round-trip disappear: the kernel's only
  output is the per-row winner vector the solver actually needs, and it is
  bit-identical to ``scatter_min`` of the legacy proposals (min is the merge
  in both, so tile order cannot matter).

  The tradeoff moved, it did not vanish: a data-dependent scatter still
  does not vectorize lane-parallel on the VPU, but the fused kernel pays it
  against VMEM instead of paying an (nnz,) HBM write + a second O(nnz) XLA
  scatter pass over HBM — per level the streamed traffic drops from ~3·nnz
  int32 plus the merge pass to 2·nnz in, (nr+1) out.  Compiled-TPU lowering
  of the in-kernel scatter is exercised by the compiled-parity tests
  (tests/test_frontier_paths.py), which run on accelerator hosts only; if
  Mosaic ever regresses on this shape the loud failure is there, and
  ``MatcherConfig(pallas_fused=False)`` restores the two-step path.
* :func:`frontier_expand_pull` (``_kernel_pull`` / ``_kernel_pull_wr``) is
  the direction-optimizing *pull* sweep: the same accumulator contract as
  the fused family, but streaming the **CSC mirror** (``radj``/``erow``, the
  row-sorted edge list of ``DeviceCSR.with_csc``).  Because the edges are
  row-sorted, each tile is a contiguous *row range*; late in a BFS most
  rows are already reached, their tiles propose nothing, and the kernel
  skips the (sequential, VPU-hostile) scatter for the whole tile via
  ``pl.when(any(proposals))`` — the per-level scatter work becomes
  proportional to the tiles that still contain unreached rows instead of
  all of them.  The proposal predicate is symmetric in edge order, and min
  is the merge, so the pull winners are bit-identical to the push families
  on the same edge set (asserted in tests/test_frontier_paths.py).

Edge geometry: callers may pass any ``block_edges >= 1``; the wrappers pad
the edge arrays up to the next tile multiple with inert sentinel edges
(``ecol = nc`` points at the NEG bfs slot so the lane never proposes,
``cadj = nr`` lands in the winner slot that is reset to IINF), replacing the
old hard ``nnz % block_edges == 0`` requirement.

``interpret=None`` auto-detects: compile for real on accelerator backends,
fall back to the Pallas interpreter only where there is no Mosaic/Triton
compiler (CPU).

VMEM budget (fused, WR, defaults): 3 state vectors of (nc+1) int32 + the
(nr+1) winner accumulator + 2 edge tiles of ``block_edges`` int32 =
4*(3*nc + nr + 2*4096) bytes ~= 16n B + 32 KiB for square graphs; n = 800k
fits the 16 MiB v5e VMEM.  Larger graphs partition the edges over the mesh
(repro.matching.ShardedMatcher) and each shard tiles its own slice.  (This
budget math is also walked through in docs/architecture.md, "The Pallas
frontier kernel".)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UNVISITED = 1          # python ints: safe to close over in kernels
IINF = 2**30
LANE = 128             # TPU lane width; the floor for any edge tile


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` = auto: interpret only where Pallas cannot compile (CPU)."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


def check_edge_geometry(nnz: int, block_edges: int) -> None:
    """Trace-time validation of the edge-tile geometry.

    Raises a typed :class:`ValueError` naming the offending shapes (the old
    code bare-asserted ``nnz % block_edges == 0`` inside a jitted wrapper,
    which surfaced as an anonymous tuple).  Divisibility itself is no longer
    required — the wrappers pad — but the tile size must be positive.
    """
    if block_edges < 1:
        raise ValueError(
            "frontier_expand: block_edges must be a positive tile size, got "
            f"block_edges={block_edges} for nnz={nnz}")


def _pad_edges(ecol, cadj, block_edges: int, nc: int, nr: int):
    """Pad the edge arrays up to a multiple of ``block_edges`` with inert
    sentinel edges (``ecol=nc`` -> NEG bfs slot, never active; ``cadj=nr`` ->
    the winner slot that is reset to IINF)."""
    nnz = ecol.shape[0]
    pad = -(-nnz // block_edges) * block_edges
    if pad != nnz:
        ecol = jnp.concatenate(
            [ecol, jnp.full(pad - nnz, jnp.int32(nc))])
        cadj = jnp.concatenate(
            [cadj, jnp.full(pad - nnz, jnp.int32(nr))])
    return ecol, cadj


def _proposals(level, ecol, cadj, bfs, root, rmatch):
    """Per-edge proposal mask (paper Alg. 2 l.6-8 / Alg. 4 l.4-10).

    ``root=None`` selects the plain (non-WR) formula.  Shared by both kernel
    families and their jnp reference oracles.
    """
    nc = bfs.shape[0] - 1
    active = jnp.take(bfs, ecol, axis=0) == level
    if root is not None:
        # WR early-exit (Alg. 4 lines 4-7)
        myroot = jnp.take(root, ecol, axis=0)
        active &= jnp.take(bfs, myroot, axis=0) >= UNVISITED
    # row -> matched column lookup (Alg. 4 lines 9-10)
    cm = jnp.take(rmatch, cadj, axis=0)
    col_unvis = jnp.take(bfs, jnp.clip(cm, 0, nc), axis=0) == UNVISITED
    return active & ((cm >= 0) & col_unvis | (cm == -1))


# ---------------------------------------------------------------------------
# Legacy kernels: per-edge proposals, merge outside
# ---------------------------------------------------------------------------
def _kernel_wr(level_ref, ecol_ref, cadj_ref, bfs_ref, root_ref, rmatch_ref,
               out_ref):
    ecol = ecol_ref[...]
    target = _proposals(level_ref[0], ecol, cadj_ref[...], bfs_ref[...],
                        root_ref[...], rmatch_ref[...])
    out_ref[...] = jnp.where(target, ecol, jnp.int32(IINF))


def _kernel_plain(level_ref, ecol_ref, cadj_ref, bfs_ref, rmatch_ref, out_ref):
    ecol = ecol_ref[...]
    target = _proposals(level_ref[0], ecol, cadj_ref[...], bfs_ref[...],
                        None, rmatch_ref[...])
    out_ref[...] = jnp.where(target, ecol, jnp.int32(IINF))


# ---------------------------------------------------------------------------
# Fused kernels: per-row winner accumulator carried across the grid
# ---------------------------------------------------------------------------
def _merge_tile(target, ecol, cadj, win_ref):
    """Tile-local min-merge into the VMEM-resident winner accumulator.

    The accumulator block is revisited by every grid step (index map is
    constant), so it stays in VMEM for the whole sweep; the TPU grid is
    sequential, making read-modify-write across steps well defined.
    """
    nr = win_ref.shape[0] - 1

    @pl.when(pl.program_id(0) == 0)
    def _init():
        win_ref[...] = jnp.full(win_ref.shape, IINF, jnp.int32)

    prop = jnp.where(target, ecol, jnp.int32(IINF))
    rows = jnp.where(target, cadj, jnp.int32(nr))
    win_ref[...] = win_ref[...].at[rows].min(prop)

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _seal():
        # the sentinel slot absorbed every non-proposal; never a winner
        win_ref[...] = win_ref[...].at[nr].set(jnp.int32(IINF))


def _kernel_fused_wr(level_ref, ecol_ref, cadj_ref, bfs_ref, root_ref,
                     rmatch_ref, win_ref):
    ecol, cadj = ecol_ref[...], cadj_ref[...]
    target = _proposals(level_ref[0], ecol, cadj, bfs_ref[...],
                        root_ref[...], rmatch_ref[...])
    _merge_tile(target, ecol, cadj, win_ref)


def _kernel_fused_plain(level_ref, ecol_ref, cadj_ref, bfs_ref, rmatch_ref,
                        win_ref):
    ecol, cadj = ecol_ref[...], cadj_ref[...]
    target = _proposals(level_ref[0], ecol, cadj, bfs_ref[...],
                        None, rmatch_ref[...])
    _merge_tile(target, ecol, cadj, win_ref)


# ---------------------------------------------------------------------------
# Pull kernels: CSC (row-sorted) edge stream, tile-skipping merge
# ---------------------------------------------------------------------------
def _merge_tile_pull(target, cols, rows, win_ref):
    """Like :func:`_merge_tile`, but the merge is predicated on the tile
    proposing anything at all.

    The pull stream is row-sorted, so a tile covers a contiguous row range;
    once those rows are reached the tile goes permanently quiet and the
    sequential in-VMEM scatter — the expensive part of the sweep — is
    skipped wholesale.  Init/seal stay unconditional (the accumulator
    contract does not depend on which tiles were quiet).
    """
    nr = win_ref.shape[0] - 1

    @pl.when(pl.program_id(0) == 0)
    def _init():
        win_ref[...] = jnp.full(win_ref.shape, IINF, jnp.int32)

    @pl.when(jnp.any(target))
    def _merge():
        prop = jnp.where(target, cols, jnp.int32(IINF))
        rows_ix = jnp.where(target, rows, jnp.int32(nr))
        win_ref[...] = win_ref[...].at[rows_ix].min(prop)

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _seal():
        win_ref[...] = win_ref[...].at[nr].set(jnp.int32(IINF))


def _kernel_pull_wr(level_ref, radj_ref, erow_ref, bfs_ref, root_ref,
                    rmatch_ref, win_ref):
    cols, rows = radj_ref[...], erow_ref[...]
    target = _proposals(level_ref[0], cols, rows, bfs_ref[...],
                        root_ref[...], rmatch_ref[...])
    _merge_tile_pull(target, cols, rows, win_ref)


def _kernel_pull(level_ref, radj_ref, erow_ref, bfs_ref, rmatch_ref, win_ref):
    cols, rows = radj_ref[...], erow_ref[...]
    target = _proposals(level_ref[0], cols, rows, bfs_ref[...],
                        None, rmatch_ref[...])
    _merge_tile_pull(target, cols, rows, win_ref)


# ---------------------------------------------------------------------------
# Public wrappers
# ---------------------------------------------------------------------------
_KERNELS = {                       # family -> (wr kernel, plain kernel)
    "legacy": (_kernel_wr, _kernel_plain),
    "fused": (_kernel_fused_wr, _kernel_fused_plain),
    "pull": (_kernel_pull_wr, _kernel_pull),
}


@functools.partial(jax.jit,
                   static_argnames=("block_edges", "interpret", "family"))
def _sweep_impl(ecol, cadj, bfs, root, rmatch, level, *, block_edges: int,
                interpret: bool, family: str):
    """One pallas_call builder for all three kernel families.

    The edge padding, grid, and every input spec are identical; the
    families differ only in kernel body and output contract (edge-tiled
    (nnz,) proposals vs the carried (nr+1,) winner accumulator).  For the
    pull family ``ecol``/``cadj`` are the CSC mirror's ``radj``/``erow`` —
    same (column, row) endpoint roles, row-sorted order.
    """
    nnz = ecol.shape[0]
    nc = bfs.shape[0] - 1
    nr = rmatch.shape[0] - 1
    ecol_p, cadj_p = _pad_edges(ecol, cadj, block_edges, nc, nr)
    grid = (ecol_p.shape[0] // block_edges,)
    level_arr = jnp.asarray(level, jnp.int32).reshape(1)

    edge_spec = pl.BlockSpec((block_edges,), lambda i: (i,))
    def rep(arr):                       # replicated per tile (VMEM-resident)
        return pl.BlockSpec(arr.shape, lambda i: (0,))

    in_specs = [pl.BlockSpec((1,), lambda i: (0,)), edge_spec, edge_spec,
                rep(bfs)]
    args = [level_arr, ecol_p, cadj_p, bfs]
    if root is not None:
        in_specs.append(rep(root))
        args.append(root)
    in_specs.append(rep(rmatch))
    args.append(rmatch)

    kernel_wr, kernel_plain = _KERNELS[family]
    kernel = kernel_wr if root is not None else kernel_plain
    if family == "legacy":
        out_specs = edge_spec
        out_shape = jax.ShapeDtypeStruct(ecol_p.shape, jnp.int32)
    else:
        out_specs = pl.BlockSpec((nr + 1,), lambda i: (0,))  # carried acc
        out_shape = jax.ShapeDtypeStruct((nr + 1,), jnp.int32)
    out = pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*args)
    return out[:nnz] if family == "legacy" else out


def frontier_expand(ecol, cadj, bfs, root, rmatch, level, *,
                    block_edges: int = 4096,
                    interpret: Optional[bool] = None):
    """Per-edge frontier proposals (legacy two-step path).

    ``root=None`` selects the plain kernel; ``interpret=None`` auto-detects
    from the backend.  The per-row merge is the caller's scatter.
    """
    check_edge_geometry(int(ecol.shape[0]), block_edges)
    return _sweep_impl(ecol, cadj, bfs, root, rmatch, level,
                       block_edges=block_edges,
                       interpret=resolve_interpret(interpret),
                       family="legacy")


def frontier_expand_fused(ecol, cadj, bfs, root, rmatch, level, *,
                          block_edges: int = 4096,
                          interpret: Optional[bool] = None):
    """Fused frontier sweep: per-row winners, merged inside the kernel.

    Returns the ``(nr+1,)`` int32 winner vector (lowest proposing column per
    row, IINF = unreached; slot ``nr`` is the IINF sentinel) — bit-identical
    to ``scatter_min`` over :func:`frontier_expand` proposals, with no
    (nnz,) intermediate.

    The carried accumulator relies on the grid executing *sequentially*
    (TPU, and the interpreter); on a parallel-grid backend (GPU/Triton) the
    read-modify-write across blocks would race, so there the same contract
    is kept by composing the legacy proposal kernel with an XLA min-scatter.
    """
    check_edge_geometry(int(ecol.shape[0]), block_edges)
    interp = resolve_interpret(interpret)
    if not interp and jax.default_backend() != "tpu":
        return _winner_via_legacy(ecol, cadj, bfs, root, rmatch, level,
                                  block_edges=block_edges)
    return _sweep_impl(ecol, cadj, bfs, root, rmatch, level,
                       block_edges=block_edges, interpret=interp,
                       family="fused")


def _winner_via_legacy(ecol, cadj, bfs, root, rmatch, level, *,
                       block_edges: int):
    """Parallel-grid (GPU/Triton) fallback keeping the winner contract:
    legacy proposal kernel composed with an XLA min-scatter — the carried
    accumulator needs a sequential grid, which only TPU (and the
    interpreter) guarantee."""
    nr = rmatch.shape[0] - 1
    prop = _sweep_impl(ecol, cadj, bfs, root, rmatch, level,
                       block_edges=block_edges, interpret=False,
                       family="legacy")
    rows = jnp.where(prop < IINF, cadj, jnp.int32(nr))
    win = jnp.full(nr + 1, IINF, jnp.int32).at[rows].min(prop)
    return win.at[nr].set(jnp.int32(IINF))


def frontier_expand_pull(radj, erow, bfs, root, rmatch, level, *,
                         block_edges: int = 4096,
                         interpret: Optional[bool] = None):
    """Pull-direction frontier sweep over the CSC mirror's row-sorted edges.

    ``radj``/``erow`` are the column/row endpoints of ``DeviceCSR.with_csc``
    (sentinels ``nc``/``nr``, same conventions as ``ecol``/``cadj``).
    Returns the same ``(nr+1,)`` winner vector as
    :func:`frontier_expand_fused` — the proposal predicate is per-edge and
    min is the merge, so edge order cannot change the winners — but tiles
    whose row range no longer contains unreached rows skip their in-VMEM
    scatter entirely (see ``_merge_tile_pull``).

    Like the fused family, the carried accumulator needs a sequential grid;
    on non-TPU compiled backends the contract is kept by the legacy
    proposal kernel + XLA min-scatter over the same (permuted) edge arrays.
    """
    check_edge_geometry(int(radj.shape[0]), block_edges)
    interp = resolve_interpret(interpret)
    if not interp and jax.default_backend() != "tpu":
        return _winner_via_legacy(radj, erow, bfs, root, rmatch, level,
                                  block_edges=block_edges)
    return _sweep_impl(radj, erow, bfs, root, rmatch, level,
                       block_edges=block_edges, interpret=interp,
                       family="pull")
