"""Public wrappers for the frontier-expansion kernels.

``frontier_expand``       — legacy per-edge proposal sweep (merge outside).
``frontier_expand_fused`` — fused sweep + in-kernel per-row winner merge.
``frontier_expand_pull``  — pull sweep over the CSC mirror (row-sorted
                            edges, tile-skipping merge, same winner
                            contract as the fused family).
``resolve_interpret``     — the backend-based interpret auto-detection shared
                            with ``repro.matching`` (interpret only on CPU).
"""
from __future__ import annotations

from .frontier_expand import (frontier_expand, frontier_expand_fused,
                              frontier_expand_pull, resolve_interpret)

__all__ = ["frontier_expand", "frontier_expand_fused",
           "frontier_expand_pull", "resolve_interpret"]
