"""Public wrappers for the frontier-expansion kernels.

``frontier_expand``       — legacy per-edge proposal sweep (merge outside).
``frontier_expand_fused`` — fused sweep + in-kernel per-row winner merge.
``resolve_interpret``     — the backend-based interpret auto-detection shared
                            with ``repro.matching`` (interpret only on CPU).
"""
from __future__ import annotations

from .frontier_expand import (frontier_expand, frontier_expand_fused,
                              resolve_interpret)

__all__ = ["frontier_expand", "frontier_expand_fused", "resolve_interpret"]
