"""Jitted public wrapper for the frontier-expansion kernel."""
from __future__ import annotations

from .frontier_expand import frontier_expand

__all__ = ["frontier_expand"]
