"""Jitted public wrapper for the flash-attention kernel."""
from __future__ import annotations

from .flash_attention import flash_attention

__all__ = ["flash_attention"]
