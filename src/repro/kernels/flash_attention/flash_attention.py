"""Pallas TPU flash attention (forward): online-softmax tiling in VMEM.

Grid: (B, KV-heads, Q-blocks); the kernel loops KV blocks with
``jax.lax.fori_loop``, keeping the (block_q x hd) accumulator, running max
and running sum in VMEM — the FlashAttention recurrence adapted to MXU tile
shapes:

* block_q x block_k = 512 x 512 (both multiples of 128 — MXU-aligned),
* per-tile VMEM: q (512*hd) + k,v (512*hd)*2 + acc (512*hd) + scores
  (512*512*4 B) ~ 1.8 MiB at hd=128 — well under 16 MiB,
* causal blocks above the diagonal are skipped via the loop upper bound
  (the classic 2x saving), masking applies only on the diagonal blocks.

GQA: queries are laid out (B, KV, G*S_q) so one kernel instance serves one
KV head; grouped queries ride along the q-block axis.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, causal, seq_k):
    # q_ref: (block_q, hd); k_ref/v_ref: (seq_k, hd); o_ref: (block_q, hd)
    block_q, hd = q_ref.shape
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale
    nkv = seq_k // block_k
    if causal:
        # skip fully-masked blocks above the diagonal (the classic 2x)
        last_kpos = (qi + 1) * block_q - 1
        nkv = jnp.minimum(nkv, last_kpos // block_k + 1)

    def body(j, carry):
        acc, m_run, l_run = carry
        k = jax.lax.dynamic_slice(k_ref[...], (j * block_k, 0),
                                  (block_k, hd)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[...], (j * block_k, 0),
                                  (block_k, hd)).astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=1)
        acc = acc * corr[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nkv, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True):
    """q: (B,S,H,hd); k/v: (B,Sk,KV,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert H % KV == 0
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0
    # Layout (B, KV*G, S, hd): one grid row per query head; the K/V BlockSpec
    # index map folds GQA (h -> h // G) so K/V are NEVER replicated G times —
    # the GQA bandwidth saving happens in the tiling itself.
    qr = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,S,hd)
    qr = qr.reshape(B, KV * G, S, hd)
    kr = k.transpose(0, 2, 1, 3)                              # (B,KV,Sk,hd)
    vr = v.transpose(0, 2, 1, 3)

    grid = (B, KV * G, S // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=hd ** -0.5, block_k=block_k,
                          causal=causal, seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, Sk, hd),
                         lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((None, None, Sk, hd),
                         lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV * G, S, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, S, H, hd)
