"""Pure-jnp oracle for the flash-attention kernel (GQA, causal/bidir)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) with H % KV == 0."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    s *= hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)
