"""Step-atomic sharded checkpointing with elastic restore.

Layout (one directory per step, manifest last -> atomicity):

  <dir>/step_<n>/
    manifest.msgpack    {tree structure, shapes, dtypes, step}   (written LAST)
    <leaf-key>.npy      one file per pytree leaf

Fault-tolerance contract:
* ``save`` writes every leaf then the manifest; a crash mid-save leaves no
  manifest, so ``latest_step`` never selects a torn checkpoint.
* ``restore(..., mesh=...)`` re-shards to whatever mesh the restart has —
  elastic scaling: a job that lost a pod restores the same arrays on the
  smaller mesh (tested in tests/test_ft.py on 4 -> 2x2 device meshes).
* On a real multi-host deployment each host writes only the leaves it owns
  (addressable shards); here single-process writes everything, and the code
  path that picks owned leaves is the same.
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree,
                    keep: int = 3) -> str:
    d = os.path.join(directory, f"step_{step:09d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        meta["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                               "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    os.replace(tmp, d)                      # atomic publish
    _gc(directory, keep)
    return d


def _gc(directory: str, keep: int):
    steps = sorted(_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def _steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.msgpack")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: Optional[int] = None,
                       mesh=None, sharding_tree=None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``.

    ``sharding_tree`` (same structure, NamedSharding leaves) re-shards each
    leaf onto ``mesh`` — pass the current job's shardings for elastic restore.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())

    flat_like = _flatten(tree_like)
    shard_flat = _flatten(sharding_tree) if sharding_tree is not None else {}
    out_flat = {}
    for key, like in flat_like.items():
        info = meta["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint at step {step} missing leaf {key}")
        arr = np.load(os.path.join(d, info["file"]))
        want_dtype = (like.dtype if hasattr(like, "dtype") else arr.dtype)
        arr = arr.astype(want_dtype)
        if key in shard_flat:
            out_flat[key] = jax.device_put(arr, shard_flat[key])
        else:
            out_flat[key] = jnp.asarray(arr)
    # rebuild tree in tree_like's structure
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, _ in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(out_flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), step
