from .steps import (build_prefill_step, build_serve_step, build_train_step,
                    cross_entropy)

__all__ = ["build_train_step", "build_serve_step", "build_prefill_step",
           "cross_entropy"]
