"""Step builders: train_step / prefill / serve_step with explicit shardings.

These are the functions the dry-run lowers and the drivers jit.  All
shardings are NamedShardings resolved from the logical specs produced at
``Model.init`` time.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, resolve_spec, set_mesh
from repro.models.common import AX_DATA, ModelConfig
from repro.optim import OptConfig, adamw_update


def cross_entropy(logits, labels, chunk: int = 512) -> jnp.ndarray:
    """Mean token cross-entropy; fp32 logsumexp in sequence chunks so the
    (B, S, V) fp32 upcast is never materialized whole (nemotron-340b's
    train_4k logits are 2.1 GB/device in bf16 — 2x that in fp32 would not)."""
    B, S, V = logits.shape
    if S <= chunk:
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)
    n = S // chunk
    lgc = logits[:, : n * chunk].reshape(B, n, chunk, V).transpose(1, 0, 2, 3)
    lbc = labels[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)

    def step(acc, xs):
        lg, lb = xs
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (lgc, lbc))
    rem = S - n * chunk
    if rem:
        lg = logits[:, n * chunk:].astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[:, n * chunk:, None],
                                   axis=-1)[..., 0]
        tot = tot + jnp.sum(lse - gold)
    return tot / (B * S)


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s)), tree,
        is_leaf=lambda s: isinstance(s, P))


def batch_sharding(mesh: Mesh, batch_tree):
    def spec_for(x):
        return NamedSharding(mesh, resolve_spec(P(AX_DATA)))
    return jax.tree.map(spec_for, batch_tree)


def build_train_step(model: Model, opt_cfg: OptConfig,
                     microbatch: int = 0):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatch > 0`` enables gradient accumulation: the global batch is
    split into ``microbatch`` sequential chunks (scan), trading step latency
    for activation memory — the standard large-model knob.
    """
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits, batch["labels"])
        if cfg.family == "moe":
            loss = loss + 0.01 * aux["lb_loss"] / max(1, cfg.n_layers)
        return loss, aux

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, grads

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            def mb(carry, mbatch):
                acc, = carry
                loss, g = grads_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc,), loss

            split = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum,), losses = jax.lax.scan(mb, (zero,), split)
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = losses.mean()
        else:
            loss, grads = grads_of(params, batch)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def build_prefill_step(model: Model):
    """Serving prefill: full forward, next-token logits only."""
    def prefill(params, batch):
        logits, _ = model.forward(params, batch, last_only=True)
        return logits

    return prefill


def build_serve_step(model: Model):
    """One decode step: (params, cache, tokens) -> (logits, cache)."""
    def serve(params, cache, tokens):
        return model.decode_step(params, cache, tokens, cache["pos"])

    return serve
