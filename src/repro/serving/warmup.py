"""Ahead-of-time warmup: compile the declared (bucket x config x warm-start
x batch) grid before traffic arrives.

The scheduler's batched dispatch hits the compile cache with the key
``((batch, nc, nr, nnz_pad[, "csc"]), config, (warm start, version),
"run_many")`` — the mirror marker appears for direction-optimizing configs,
whose admissions carry the CSC mirror.
Warming exactly that grid — every declared :class:`SizeBucket`, every served
config/warm-start pair, every :func:`batch_ladder` rung — means the first
real request on a warmed bucket *never* pays a trace or compile: its
dispatch is a pure cache hit (asserted in ``tests/test_serving.py``).

Warmup drives each program with a synthetic *empty* graph of the bucket's
exact shape: all edges are inert sentinels, so the solver terminates after
one phase, but the traced program is byte-identical to the one real members
of the bucket will use (shapes are all that matter to the cache).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.matching import MatcherConfig
from repro.matching.cache import compile_cache_thread_info
from repro.matching.device_csr import DeviceCSR

from .bucketizer import SizeBucket
from .scheduler import batch_ladder


def synthetic_bucket_graph(bucket: SizeBucket, csc: bool = False
                           ) -> DeviceCSR:
    """An empty (all-sentinel-edges) graph of exactly the bucket's shape.

    Solves in O(1) phases yet forces the same compiled program as any real
    member of the bucket.  ``csc`` attaches the CSC mirror — compiled
    programs key on its presence (it adds pytree leaves), so warming a
    direction-optimizing config needs the mirrored shape.
    """
    g = DeviceCSR(
        cxadj=jnp.zeros(bucket.nc + 1, jnp.int32),
        cadj=jnp.full(bucket.nnz_pad, bucket.nr, jnp.int32),
        ecol=jnp.full(bucket.nnz_pad, bucket.nc, jnp.int32),
        nnz=jnp.int32(0), nc=bucket.nc, nr=bucket.nr)
    return g.with_csc() if csc else g


@dataclasses.dataclass(frozen=True)
class WarmupGrid:
    """The declared serving surface to compile ahead of time."""

    buckets: Tuple[SizeBucket, ...]
    configs: Tuple[MatcherConfig, ...]
    warm_starts: Tuple[str, ...]
    batch_sizes: Tuple[int, ...]

    def cells(self):
        return itertools.product(self.buckets, self.configs,
                                 self.warm_starts, self.batch_sizes)

    def __len__(self) -> int:
        return (len(self.buckets) * len(self.configs)
                * len(self.warm_starts) * len(self.batch_sizes))


@dataclasses.dataclass(frozen=True)
class WarmupReport:
    cells: int          # grid cells driven
    compiled: int       # programs actually built (cache misses)
    already: int        # cells that were already resident (cache hits)
    seconds: float

    def __str__(self) -> str:
        return (f"warmup: {self.cells} cells, {self.compiled} compiled, "
                f"{self.already} already resident, {self.seconds:.2f}s")


def warm_up(service, grid: Optional[WarmupGrid] = None) -> WarmupReport:
    """Drive every grid cell through the service's matchers.

    With ``grid=None`` the grid is derived from the service's declared
    surface: its bucketizer's buckets, its default config and warm start, and
    the batch ladder up to its ``max_batch``.  Blocks until every program has
    finished its (trivial) solve, i.e. until compilation is done.
    """
    if grid is None:
        grid = WarmupGrid(buckets=tuple(service.bucketizer.buckets),
                          configs=(service.config,),
                          warm_starts=(service.warm_start,),
                          batch_sizes=batch_ladder(service.max_batch))
    t0 = time.perf_counter()
    # per-thread deltas: warmup compiles on the calling thread, so another
    # thread's compiles (a flush, a second service warming) can't skew the
    # report
    info0 = compile_cache_thread_info()
    outs, cells = [], 0
    for bucket, cfg, ws, bs in grid.cells():
        # the mirror marker must match what admission will attach for this
        # config, or the warmed program would differ from the served one
        csc = cfg.dirop or service.bucketizer.build_csc
        g = synthetic_bucket_graph(bucket, csc=csc)
        batch = DeviceCSR.stack([g] * bs)
        outs.append(service.matcher(cfg, ws).run_many(batch).cmatch)
        cells += 1
    jax.block_until_ready(outs)
    info1 = compile_cache_thread_info()
    compiled = info1["misses"] - info0["misses"]
    return WarmupReport(cells=cells, compiled=compiled,
                        already=cells - compiled,
                        seconds=time.perf_counter() - t0)
