"""Service observability: queue wait, batch occupancy, pad waste, compile hits.

All counters live behind one lock (``submit`` threads, the flush thread, and
metric readers race them); latency-shaped series go into bounded reservoirs
so a long-running service reports percentiles at O(1) memory.  Occupancy and
pad waste are the two prices the bucketizer/scheduler pay for bounded
compilation — a deployment watches them to re-size its bucket ladder and
batch targets.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Iterable, List


def percentile(xs: Iterable[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); NaN on an empty series."""
    s: List[float] = sorted(xs)
    if not s:
        return math.nan
    k = max(0, min(len(s) - 1, round(p / 100.0 * (len(s) - 1))))
    return s[k]


class ServiceMetrics:
    """Thread-safe counters for one :class:`MatchingService`."""

    def __init__(self, reservoir: int = 4096):
        self._lock = threading.RLock()   # snapshot() reads the properties
        # request lifecycle
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0           # typed admission rejections
        self.sharded = 0            # oversize requests routed to ShardedMatcher
        # fault-tolerance lifecycle (see docs/architecture.md, the
        # degradation ladder): with `pending` these make the flush mix sum
        # to submissions —
        #   submitted == completed + failed + cancelled + shed_oldest
        #                + deadline_misses + pending
        # (shed_newest requests were refused at submit and are NOT in
        # `submitted`, mirroring `rejected`)
        self.cancelled = 0          # futures cancelled before their flush
        self.shed_newest = 0        # submits refused by backpressure
        self.shed_oldest = 0        # queued requests evicted for new ones
        self.deadline_misses = 0    # expired before dispatch, shed at flush
        self.quarantined = 0        # poisoned requests isolated by bisection
        self.restarts = 0           # flush-thread supervisor restarts
        # dispatch accounting (one device dispatch per flush)
        self.dispatches = 0
        self.flushes = {"full": 0, "deadline": 0, "drain": 0}
        self.batch_real = 0         # real requests across all flushes
        self.batch_padded = 0       # padded batch lanes across all flushes
        # pad-waste accounting (admission time)
        self.edges_true = 0
        self.edges_padded = 0
        # compile-cache deltas attributed to dispatches
        self.compile_hits = 0
        self.compile_misses = 0
        # latency reservoirs (seconds)
        self.queue_wait_s: deque = deque(maxlen=reservoir)
        self.latency_s: deque = deque(maxlen=reservoir)

    # -- recording ------------------------------------------------------------
    def record_submit(self, nnz: int, nnz_pad: int) -> None:
        with self._lock:
            self.submitted += 1
            self.edges_true += nnz
            self.edges_padded += nnz_pad

    def record_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_sharded(self) -> None:
        with self._lock:
            self.sharded += 1
            self.dispatches += 1

    def record_flush(self, reason: str, real: int, padded: int,
                     hits: int, misses: int) -> None:
        with self._lock:
            self.dispatches += 1
            self.flushes[reason] = self.flushes.get(reason, 0) + 1
            self.batch_real += real
            self.batch_padded += padded
            self.compile_hits += hits
            self.compile_misses += misses

    def record_done(self, queue_wait_s: float, latency_s: float) -> None:
        with self._lock:
            self.completed += 1
            self.queue_wait_s.append(queue_wait_s)
            self.latency_s.append(latency_s)

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def record_cancelled(self, n: int = 1) -> None:
        with self._lock:
            self.cancelled += n

    def record_shed(self, policy: str, n: int = 1) -> None:
        with self._lock:
            if policy == "reject-newest":
                self.shed_newest += n
            else:
                self.shed_oldest += n

    def record_deadline_miss(self, n: int = 1) -> None:
        with self._lock:
            self.deadline_misses += n

    def record_quarantined(self, n: int = 1) -> None:
        with self._lock:
            self.quarantined += n

    def record_restart(self) -> None:
        with self._lock:
            self.restarts += 1

    # -- reading --------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Real requests per padded batch lane, over all flushes."""
        with self._lock:
            return self.batch_real / max(1, self.batch_padded)

    @property
    def pad_edge_waste(self) -> float:
        """Fraction of admitted edge slots that are padding."""
        with self._lock:
            return 1.0 - self.edges_true / max(1, self.edges_padded)

    def snapshot(self) -> dict:
        """One consistent host-side view of every counter."""
        with self._lock:
            qs, ls = list(self.queue_wait_s), list(self.latency_s)
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "rejected": self.rejected,
                "sharded": self.sharded,
                "cancelled": self.cancelled,
                "shed_newest": self.shed_newest,
                "shed_oldest": self.shed_oldest,
                "deadline_misses": self.deadline_misses,
                "quarantined": self.quarantined,
                "restarts": self.restarts,
                "dispatches": self.dispatches,
                "flushes_full": self.flushes.get("full", 0),
                "flushes_deadline": self.flushes.get("deadline", 0),
                "flushes_drain": self.flushes.get("drain", 0),
                "batch_real": self.batch_real,
                "batch_padded": self.batch_padded,
                "occupancy": self.occupancy,
                "pad_edge_waste": self.pad_edge_waste,
                "compile_hits": self.compile_hits,
                "compile_misses": self.compile_misses,
                "queue_wait_p50_ms": percentile(qs, 50) * 1e3,
                "queue_wait_p99_ms": percentile(qs, 99) * 1e3,
                "latency_p50_ms": percentile(ls, 50) * 1e3,
                "latency_p99_ms": percentile(ls, 99) * 1e3,
            }
