"""Admission control: map raw incoming graphs onto declared size buckets.

A serving deployment cannot afford one compiled program per arriving shape —
every novel ``(nc, nr, nnz_pad)`` would pay a trace+compile on the request
path and eventually thrash the compile cache.  The bucketizer declares a
finite grid of :class:`SizeBucket` shapes up front (the same grid the AOT
warmup in :mod:`repro.serving.warmup` compiles), places each incoming graph
in the smallest declared bucket that fits — padding vertices
(:meth:`DeviceCSR.pad_vertices`) and edges with inert sentinels — and
accounts the padding waste per admission.  Graphs that fit no bucket are
either routed to the edge-sharded :class:`~repro.matching.ShardedMatcher`
lane (``oversize="shard"``) or rejected with the typed
:class:`OversizeGraphError` (``oversize="reject"``), so the caller can
distinguish admission failure from solver failure.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.csr import BipartiteCSR
from repro.matching.device_csr import (LANE, DeviceCSR, GraphValidationError,
                                       bucket_nnz, validate_structure)


class OversizeGraphError(ValueError):
    """Typed admission rejection: the graph fits no declared bucket."""

    def __init__(self, nc: int, nr: int, nnz: int, largest: "SizeBucket"):
        self.nc, self.nr, self.nnz = nc, nr, nnz
        self.largest = largest
        super().__init__(
            f"graph ({nc}x{nr}, {nnz} edges) fits no declared bucket; "
            f"largest is ({largest.nc}x{largest.nr}, {largest.nnz_pad} edge "
            f"slots) — enlarge the ladder or serve with oversize='shard'")


@dataclasses.dataclass(frozen=True, order=True)
class SizeBucket:
    """One declared compiled shape: (nc, nr, edge capacity)."""

    nc: int
    nr: int
    nnz_pad: int

    def fits(self, nc: int, nr: int, nnz: int) -> bool:
        return nc <= self.nc and nr <= self.nr and nnz <= self.nnz_pad

    @property
    def cost(self) -> int:
        """Padded footprint in int32 words — the order buckets are tried in."""
        return 2 * self.nnz_pad + self.nc + self.nr

    @property
    def key(self) -> Tuple[int, int, int]:
        return (self.nc, self.nr, self.nnz_pad)


def ladder(max_vertices: int = 4096, min_vertices: int = 256,
           edge_factor: int = 8, lane: int = LANE) -> Tuple[SizeBucket, ...]:
    """Geometric default grid: square ``(v, v)`` buckets, doubling ``v`` from
    ``min_vertices`` to ``max_vertices``, each holding ``v * edge_factor``
    edges (rounded to the canonical power-of-two capacity)."""
    assert min_vertices <= max_vertices, (min_vertices, max_vertices)
    out, v = [], min_vertices
    while v <= max_vertices:
        out.append(SizeBucket(v, v, bucket_nnz(v * edge_factor, lane)))
        v *= 2
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class Admission:
    """One admitted request: the bucket-shaped device graph + accounting."""

    graph: DeviceCSR
    bucket: Optional[SizeBucket]      # None on the sharded route
    route: str                        # "bucket" | "sharded"
    nc: int                           # true sizes of the submitted graph
    nr: int
    nnz: int

    @property
    def pad_edges(self) -> int:
        """Wasted edge slots this admission pays for."""
        return self.graph.nnz_pad - self.nnz

    @property
    def pad_vertex_slots(self) -> int:
        """Wasted vertex slots (isolated padding columns + rows)."""
        return (self.graph.nc - self.nc) + (self.graph.nr - self.nr)


def _pad_host_vertices(g: BipartiteCSR, nc: int, nr: int,
                       nnz_pad: int) -> BipartiteCSR:
    """Host-side vertex+edge padding in one rebuild (extra columns have an
    empty CSR segment; sentinels take the new ``nc``/``nr``)."""
    cxadj = g.cxadj
    if nc > g.nc:
        cxadj = np.concatenate(
            [cxadj, np.full(nc - g.nc, g.nnz, np.int32)])
    return BipartiteCSR.from_csr(cxadj, g.cadj[: g.nnz], nc, nr,
                                 pad_to=nnz_pad)


class Bucketizer:
    """Maps raw graphs onto the declared bucket grid (or the sharded lane).

    ``buckets`` default to :func:`ladder`.  ``oversize`` selects the policy
    for graphs that fit no bucket: ``"reject"`` raises
    :class:`OversizeGraphError`; ``"shard"`` admits them with
    ``route="sharded"`` for the service to hand to ``ShardedMatcher``.
    ``build_csc`` attaches the CSC mirror (:meth:`DeviceCSR.with_csc`) to
    every admitted graph — required by direction-optimizing configs
    (``MatcherConfig(dirop=True)``); the service also requests it per
    admission when the request's config needs it, so this default only
    matters for callers using the bucketizer directly.
    ``validate`` runs the :func:`repro.matching.validate_structure`
    invariants on every admission and raises the typed
    :class:`~repro.matching.GraphValidationError` on malformed input —
    the first rung of the serving failure ladder
    (:class:`~repro.serving.service.MatchingService` turns it on by
    default for the bucketizers it builds itself).
    """

    def __init__(self, buckets: Optional[Sequence[SizeBucket]] = None,
                 oversize: str = "reject", build_csc: bool = False,
                 validate: bool = False):
        assert oversize in ("reject", "shard"), oversize
        bs = tuple(sorted(buckets if buckets is not None else ladder(),
                          key=lambda b: b.cost))
        assert bs, "need at least one declared bucket"
        self.buckets = bs
        self.oversize = oversize
        self.build_csc = build_csc
        self.validate = validate

    def bucket_for(self, nc: int, nr: int, nnz: int) -> Optional[SizeBucket]:
        """Smallest (by padded footprint) declared bucket that fits."""
        for b in self.buckets:
            if b.fits(nc, nr, nnz):
                return b
        return None

    def admit(self, graph: Union[BipartiteCSR, DeviceCSR],
              csc: Optional[bool] = None) -> Admission:
        """Place ``graph`` in a bucket (pad + upload) or route/reject it.

        Accepts the host container or an already-uploaded ``DeviceCSR``
        (whose true ``nnz`` costs one scalar sync at admission — the padded
        edges must sit at the array tail, as every constructor here lays
        them out).  ``csc`` overrides the bucketizer's ``build_csc`` default
        per admission (the service passes ``config.dirop``); the mirror is
        built on the bucket-shaped graph so it pads/stacks/shards with it.
        """
        csc = self.build_csc if csc is None else csc
        if isinstance(graph, BipartiteCSR):
            nc, nr, nnz = graph.nc, graph.nr, graph.nnz
        elif isinstance(graph, DeviceCSR):
            assert not graph.batch_shape, "admit() takes a single graph"
            # a pre-attached mirror would not survive the bucket reshaping
            # below (the trim path slices only the CSR arrays); rebuild it
            # on the bucket-shaped graph instead
            graph = graph.drop_csc()
            nc, nr, nnz = graph.nc, graph.nr, int(graph.nnz)
        else:
            raise TypeError(
                f"admit() takes BipartiteCSR or DeviceCSR, got {type(graph)}"
                " — build edge lists with Bucketizer.from_edges")
        if self.validate:
            # garbage is rejected HERE, before it can reach a kernel where
            # out-of-range ids would be clamped into silently-wrong
            # matchings or poison a whole co-batched dispatch
            if isinstance(graph, BipartiteCSR):
                problems = validate_structure(graph.cxadj, graph.cadj,
                                              graph.ecol, nnz, nc, nr)
                if problems:
                    raise GraphValidationError(problems)
            else:
                graph.validate()
        b = self.bucket_for(nc, nr, nnz)
        if b is None:
            if self.oversize == "reject":
                raise OversizeGraphError(nc, nr, nnz, self.buckets[-1])
            dev = (graph if isinstance(graph, DeviceCSR)
                   else DeviceCSR.from_host(graph)).bucketed()
            if csc:
                dev = dev.with_csc()
            return Admission(graph=dev, bucket=None, route="sharded",
                             nc=nc, nr=nr, nnz=nnz)
        if isinstance(graph, BipartiteCSR):
            dev = DeviceCSR.from_host(
                _pad_host_vertices(graph, b.nc, b.nr, b.nnz_pad))
        else:
            dev = graph.pad_vertices(b.nc, b.nr)
            if dev.nnz_pad > b.nnz_pad:      # over-padded upload: trim tail
                dev = dataclasses.replace(dev,
                                          cadj=dev.cadj[: b.nnz_pad],
                                          ecol=dev.ecol[: b.nnz_pad])
            else:
                dev = dev.pad_to(b.nnz_pad)
        if csc:
            dev = dev.with_csc()
        return Admission(graph=dev, bucket=b, route="bucket",
                         nc=nc, nr=nr, nnz=nnz)

    @staticmethod
    def from_edges(cols, rows, nc: int, nr: int) -> BipartiteCSR:
        """Convenience for raw edge-list requests (dedups, builds CSR)."""
        return BipartiteCSR.from_edges(np.asarray(cols), np.asarray(rows),
                                       nc, nr)
