"""Adaptive micro-batcher: accumulate per-key, flush on batch-full or deadline.

Requests accumulate in per-``(bucket, config, warm start)`` queues — only
same-key requests can share one ``match_many`` dispatch.  A queue flushes
when it reaches its batch target ("full") or when its oldest request has
waited ``max_delay_s`` ("deadline"), so tail latency is bounded no matter how
quiet a bucket is.

The batch target is adaptive, per key: it starts at 1 — the
latency-optimal choice when traffic is sparse — doubles every time a flush
fills (arrivals are outpacing dispatch, so larger batches amortize more
per-call overhead, the paper's core premise), and drops to the observed
size on every deadline flush (a deadline firing is direct evidence the
target was not reachable in time).  Under sustained load the target climbs
to ``max_batch`` within ``log2(max_batch)`` flushes; when load thins, one
deadline flush pulls it straight back down.  ``adaptive=False`` pins the
target at ``max_batch`` (pure throughput mode).

Flushed sizes are rounded up to the :func:`batch_ladder` (powers of two
capped at ``max_batch``) by the dispatcher, so the compile cache sees
O(log max_batch) batch shapes per bucket — the exact grid AOT warmup
compiles.

This class is deliberately *not* thread-safe: :class:`~repro.serving.service.
MatchingService` serializes access under its own condition variable, which
keeps the flush policy a plain data structure testable with a fake clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Hashable, List, Optional, Tuple


def batch_ladder(max_batch: int) -> Tuple[int, ...]:
    """Padded batch sizes a dispatcher may issue: 1, 2, 4, ... , max_batch."""
    assert max_batch >= 1, max_batch
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(dict.fromkeys(out))


def batch_bucket(n: int, max_batch: int) -> int:
    """Round a flush of ``n`` requests up to its ladder rung."""
    assert 1 <= n <= max_batch, (n, max_batch)
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


@dataclasses.dataclass(frozen=True)
class Queued:
    """One enqueued request: opaque payload + its enqueue timestamp."""

    payload: object
    enqueued_at: float


@dataclasses.dataclass(frozen=True)
class Flush:
    """A batch ready to dispatch (one device dispatch per Flush)."""

    key: Hashable
    items: Tuple[Queued, ...]
    reason: str                  # "full" | "deadline" | "drain"
    target: int                  # the batch target when the flush fired


class MicroBatcher:
    """Per-key accumulation with full/deadline/drain flushes (see module doc).

    The caller drives time explicitly (``now``) — nothing here reads a clock.
    """

    def __init__(self, max_batch: int = 8, max_delay_s: float = 0.002,
                 adaptive: bool = True):
        assert max_batch >= 1 and max_delay_s >= 0
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.adaptive = adaptive
        self._queues: Dict[Hashable, List[Queued]] = {}
        self._target: Dict[Hashable, float] = {}

    # -- policy ---------------------------------------------------------------
    def target(self, key: Hashable) -> int:
        """Current batch target for ``key`` (clamped to [1, max_batch])."""
        if not self.adaptive:
            return self.max_batch
        t = self._target.get(key, 1.0)
        return max(1, min(self.max_batch, math.ceil(t)))

    def _adapt(self, key: Hashable, size: int, reason: str) -> None:
        if not self.adaptive:
            return
        t = self._target.get(key, 1.0)
        if reason == "full":
            self._target[key] = min(float(self.max_batch), max(2.0, 2.0 * t))
        elif reason == "deadline":
            # a deadline fired => arrivals did not fill the target in time;
            # drop straight to the observed size (an averaged decay never
            # reaches 1 under ceil(), leaving sparse traffic stuck paying
            # the full deadline on every request)
            self._target[key] = max(1.0, float(size))

    # -- queue operations -----------------------------------------------------
    def add(self, key: Hashable, payload: object, now: float
            ) -> Optional[Flush]:
        """Enqueue; returns a full-batch Flush if the target was reached."""
        q = self._queues.setdefault(key, [])
        q.append(Queued(payload, now))
        if len(q) >= self.target(key):
            return self._flush(key, "full")
        return None

    def _flush(self, key: Hashable, reason: str) -> Optional[Flush]:
        q = self._queues.pop(key, [])
        if not q:
            return None
        tgt = self.target(key)
        self._adapt(key, len(q), reason)
        return Flush(key=key, items=tuple(q), reason=reason, target=tgt)

    def due(self, now: float) -> List[Flush]:
        """Deadline flushes: every queue whose oldest request has expired."""
        expired = [k for k, q in self._queues.items()
                   if q and now - q[0].enqueued_at >= self.max_delay_s]
        return [f for k in expired if (f := self._flush(k, "deadline"))]

    def next_deadline(self) -> Optional[float]:
        """Absolute time of the earliest pending deadline (None if idle)."""
        ts = [q[0].enqueued_at + self.max_delay_s
              for q in self._queues.values() if q]
        return min(ts) if ts else None

    def evict_oldest(self) -> Optional[Queued]:
        """Pop the single oldest queued request across every key.

        The service's ``reject-oldest`` shed policy: when the bounded
        admission queue is full, the request that has waited longest — and
        is therefore the most likely to miss its deadline anyway — makes
        room for the incoming one.  Returns ``None`` when nothing is queued.
        """
        best_key, best = None, None
        for k, q in self._queues.items():
            if q and (best is None or q[0].enqueued_at < best.enqueued_at):
                best_key, best = k, q[0]
        if best is None:
            return None
        q = self._queues[best_key]
        q.pop(0)
        if not q:
            del self._queues[best_key]
        return best

    def oldest_enqueued_at(self) -> Optional[float]:
        """Enqueue time of the request :meth:`evict_oldest` would pop."""
        ts = [q[0].enqueued_at for q in self._queues.values() if q]
        return min(ts) if ts else None

    def drain(self) -> List[Flush]:
        """Flush every non-empty queue immediately (graceful drain)."""
        return [f for k in list(self._queues)
                if (f := self._flush(k, "drain"))]

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())
