"""``repro.serving`` — the online matching service over ``repro.matching``.

The paper's claim is that GPU matching wins once per-call overhead is
amortized and the solve stays device-resident; this package is the layer
that realizes it under live traffic: requests are admitted onto declared
size buckets (:mod:`bucketizer`), micro-batched per (bucket, config, warm
start) with adaptive targets and deadline flushes (:mod:`scheduler`),
dispatched as ONE ``match_many`` call per flush (:mod:`service`), with the
whole (bucket x config x warm-start x batch) grid compiled ahead of time
(:mod:`warmup`) and everything observable (:mod:`metrics`)::

    submit() ─► Bucketizer ─► MicroBatcher ─► stack + match_many ─► Future
                   │ oversize                        (1 dispatch/flush)
                   └─────────► ShardedMatcher lane

``python -m repro.launch.serve_matching`` replays a synthetic open-loop
traffic trace against this service; ``benchmarks/serving.py`` sweeps offered
load; ``docs/architecture.md`` ("The serving layer") documents the design.
"""
from .bucketizer import (Admission, Bucketizer, OversizeGraphError,
                         SizeBucket, ladder)
from .faults import (CompileFault, FaultInjector, FlushThreadDeath,
                     InjectedFault, PoisonedGraphFault)
from .metrics import ServiceMetrics, percentile
from .scheduler import Flush, MicroBatcher, batch_bucket, batch_ladder
from .service import (DeadlineExceededError, FlushThreadDiedError,
                      MatchingService, MatchResult, QueueFullError,
                      ServiceClosedError, SheddedError)
from .warmup import (WarmupGrid, WarmupReport, synthetic_bucket_graph,
                     warm_up)

__all__ = [
    "Admission", "Bucketizer", "OversizeGraphError", "SizeBucket", "ladder",
    "CompileFault", "FaultInjector", "FlushThreadDeath", "InjectedFault",
    "PoisonedGraphFault",
    "ServiceMetrics", "percentile",
    "Flush", "MicroBatcher", "batch_bucket", "batch_ladder",
    "MatchingService", "MatchResult", "ServiceClosedError",
    "DeadlineExceededError", "FlushThreadDiedError", "QueueFullError",
    "SheddedError",
    "WarmupGrid", "WarmupReport", "synthetic_bucket_graph", "warm_up",
]
