"""Deterministic fault injection for the serving stack (chaos harness).

Real failure modes a long-running matching service meets — a poisoned graph
that kills its whole co-batched dispatch, a transient XLA/compile error, a
slow device, a crashed flush thread — are injected here as *deterministic,
seedable* hooks so every recovery path in :class:`~repro.serving.service.
MatchingService` (bisection quarantine, retry backoff, supervisor restart)
is drivable from a unit test without real hardware faults::

    faults = FaultInjector(seed=7)
    faults.poison("bad-req")              # every batch containing it fails
    faults.script(RuntimeError("flaky"))  # next dispatch fails once
    faults.kill_thread_after(3)           # 4th dispatch kills the flush thread
    svc = MatchingService(..., faults=faults)

The service calls :meth:`FaultInjector.before_dispatch` on the flush thread
immediately before each device dispatch (batched and sharded lanes alike);
the injector may sleep (latency), raise :class:`InjectedFault` /
:class:`CompileFault` (recoverable — the service bisects/retries), or raise
:class:`FlushThreadDeath` (a ``BaseException``, so it sails past the
service's per-flush ``except Exception`` guards and genuinely kills the
thread, exactly like a native crash would).  All decisions draw from one
seeded ``random.Random`` under a lock, so a given (seed, request sequence)
replays identically.

``python -m repro.launch.serve_matching --chaos`` drives a live service
through this injector; ``tests/test_serving_faults.py`` is the scripted
matrix.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from typing import List, Optional


class InjectedFault(RuntimeError):
    """A deterministic dispatch failure planted by :class:`FaultInjector`."""


class PoisonedGraphFault(InjectedFault):
    """The injected failure a poisoned request causes in any batch that
    contains it — the stand-in for 'this graph crashes the kernel'."""

    def __init__(self, tag: str):
        self.tag = tag
        super().__init__(f"poisoned request {tag!r} crashed the dispatch")


class CompileFault(InjectedFault):
    """An injected compile-path failure (e.g. OOM while lowering)."""


class FlushThreadDeath(BaseException):
    """Injected flush-thread crash.

    Deliberately a ``BaseException``: the service's dispatch guards catch
    ``Exception`` to keep the thread alive through request failures, and a
    simulated crash must NOT be survivable by those guards — the supervisor
    path is what's under test.
    """


class FaultInjector:
    """Seedable fault hooks for :class:`~repro.serving.service.
    MatchingService` (see module docstring for the failure menu).

    Thread-safe: ``before_dispatch`` runs on the flush thread while tests
    poison/script from their own thread.
    """

    def __init__(self, seed: int = 0, dispatch_error_rate: float = 0.0,
                 compile_error_rate: float = 0.0, latency_s: float = 0.0):
        assert 0.0 <= dispatch_error_rate <= 1.0, dispatch_error_rate
        assert 0.0 <= compile_error_rate <= 1.0, compile_error_rate
        self.seed = seed
        self.dispatch_error_rate = dispatch_error_rate
        self.compile_error_rate = compile_error_rate
        self.latency_s = latency_s
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._poisoned = set()
        self._scripted: deque = deque()
        self._kill_after: Optional[int] = None
        # observability (read by tests/CLI after a run)
        self.dispatches = 0
        self.injected = 0
        self.kills = 0

    # -- planting -------------------------------------------------------------
    def poison(self, tag: str) -> None:
        """Mark the request tagged ``tag`` (``submit(..., tag=...)``) as
        poisoned: every dispatch whose batch contains it raises
        :class:`PoisonedGraphFault` — deterministically, retries included —
        until :meth:`cure` is called."""
        with self._lock:
            self._poisoned.add(tag)

    def cure(self, tag: str) -> None:
        with self._lock:
            self._poisoned.discard(tag)

    def script(self, *excs: BaseException) -> None:
        """Queue exceptions to raise on the next dispatches, one each, ahead
        of every probabilistic fault (transient-failure scenarios)."""
        with self._lock:
            self._scripted.extend(excs)

    def kill_thread_after(self, dispatches: int) -> None:
        """Arm a one-shot :class:`FlushThreadDeath` once ``dispatches`` more
        dispatches have completed (0 = the very next one dies)."""
        with self._lock:
            self._kill_after = dispatches

    # -- the service-side hook ------------------------------------------------
    def before_dispatch(self, reqs: List[object]) -> None:
        """Called by the service right before a device dispatch of ``reqs``
        (objects with a ``tag`` attribute).  Raises or sleeps per the
        planted faults; otherwise returns and the dispatch proceeds."""
        with self._lock:
            self.dispatches += 1
            if self._kill_after is not None:
                if self._kill_after <= 0:
                    self._kill_after = None
                    self.kills += 1
                    raise FlushThreadDeath()
                self._kill_after -= 1
            if self._scripted:
                self.injected += 1
                raise self._scripted.popleft()
            bad = [getattr(r, "tag", None) for r in reqs
                   if getattr(r, "tag", None) in self._poisoned]
            if bad:
                self.injected += 1
                raise PoisonedGraphFault(bad[0])
            if (self.dispatch_error_rate
                    and self._rng.random() < self.dispatch_error_rate):
                self.injected += 1
                raise InjectedFault("injected transient dispatch failure")
            if (self.compile_error_rate
                    and self._rng.random() < self.compile_error_rate):
                self.injected += 1
                raise CompileFault("injected compile failure")
            delay = self.latency_s
        if delay:
            time.sleep(delay)
