"""``MatchingService``: the online facade over the device-resident matcher.

Request path::

    submit(graph) ── Bucketizer.admit ──► per-(bucket, config, warm-start)
        │                                 queue in the MicroBatcher
        └─► Future[MatchResult]                 │ full / deadline / drain
                                                ▼
                        flush thread: DeviceCSR.stack + ONE
                        Matcher.run_many dispatch per flush,
                        then per-request MatchState slicing

``submit`` is non-blocking and returns a ``concurrent.futures.Future``; a
single background thread owns every device dispatch (batched buckets and the
oversize sharded lane), so callers never contend on the accelerator.  Flushed
batches are padded to the :func:`batch_ladder` rung with copies of the first
graph (inert lanes, results discarded) so the compile cache sees only the
batch shapes AOT warmup declared.  ``drain()`` flushes everything queued and
blocks until every accepted request resolved; ``close()`` drains and stops
the thread (also via the context-manager protocol).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple, Union

import jax

from repro.core.csr import BipartiteCSR
from repro.matching import (DeviceCSR, Matcher, MatcherConfig, MatchState,
                            MatchStats, ShardedMatcher)
from repro.matching.cache import compile_cache_thread_info

from .bucketizer import (Admission, Bucketizer, OversizeGraphError,
                         SizeBucket)
from .metrics import ServiceMetrics
from .scheduler import Flush, MicroBatcher, batch_bucket


class ServiceClosedError(RuntimeError):
    """submit() after close(): the flush thread is gone."""


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """One resolved request: the sliced device state + serving accounting."""

    state: MatchState                 # bucket-shaped (padded) matching state
    stats: MatchStats
    bucket: Optional[SizeBucket]      # None on the sharded route
    route: str                        # "bucket" | "sharded"
    nc: int                           # true submitted sizes
    nr: int
    batch_size: int                   # real requests in the flush served with
    queue_wait_s: float
    latency_s: float

    @property
    def cardinality(self) -> int:
        """Matched pairs (host sync; padding vertices are isolated, so this
        equals the true graph's maximum matching cardinality)."""
        return int(self.stats.cardinality)

    def matching(self):
        """(cmatch, rmatch) as true-size numpy arrays (bucket padding cut)."""
        cm, rm = self.state.to_host()
        return cm[: self.nc], rm[: self.nr]


@dataclasses.dataclass
class _Request:
    admission: Admission
    config: MatcherConfig
    warm_start: str
    future: Future
    submitted_at: float


class MatchingService:
    """Accepts concurrent matching requests, serves them micro-batched.

    >>> svc = MatchingService(bucketizer=Bucketizer(buckets), max_batch=8)
    >>> svc.warm_up()                        # AOT: first dispatch = cache hit
    >>> fut = svc.submit(host_graph)         # non-blocking
    >>> fut.result().cardinality
    """

    def __init__(self, bucketizer: Optional[Bucketizer] = None,
                 config: MatcherConfig = MatcherConfig(),
                 warm_start: str = "cheap",
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 mesh=None, shard_axis: str = "data",
                 adaptive: bool = True,
                 metrics: Optional[ServiceMetrics] = None):
        if bucketizer is None:
            bucketizer = Bucketizer(
                oversize="shard" if mesh is not None else "reject")
        assert bucketizer.oversize != "shard" or mesh is not None, \
            "oversize='shard' needs a mesh to shard over"
        self.bucketizer = bucketizer
        self.config = config
        self.warm_start = warm_start
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self._batcher = MicroBatcher(max_batch=max_batch,
                                     max_delay_s=max_delay_ms / 1e3,
                                     adaptive=adaptive)
        self._matchers: Dict[Tuple[MatcherConfig, str], Matcher] = {}
        self._sharded: Dict[Tuple[MatcherConfig, str], ShardedMatcher] = {}
        self.matcher()     # validate the default config/warm start eagerly
        self._cond = threading.Condition()
        self._ready: List[Flush] = []
        self._sharded_q: List[_Request] = []
        self._inflight = 0
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, name="matching-service-flush", daemon=True)
        self._thread.start()

    # -- matcher registry (shared with warmup so cache keys line up) ---------
    @property
    def max_batch(self) -> int:
        return self._batcher.max_batch

    def matcher(self, config: Optional[MatcherConfig] = None,
                warm_start: Optional[str] = None) -> Matcher:
        cfg = config if config is not None else self.config
        ws = warm_start if warm_start is not None else self.warm_start
        if cfg.adaptive_frontier:
            # run_many (the only dispatch path here) refuses this config;
            # surface that in the caller's thread, not on the flush thread
            # after the batching delay (dirop is the batch-safe variant)
            raise ValueError(
                "adaptive_frontier cannot be served (Matcher.run_many "
                "refuses it under vmap); use MatcherConfig(dirop=True)")
        key = (cfg, ws)
        m = self._matchers.get(key)
        if m is None:
            m = self._matchers[key] = Matcher(cfg, ws)
        return m

    def warm_up(self, grid=None):
        """AOT-compile the declared grid (see :mod:`repro.serving.warmup`)."""
        from .warmup import warm_up
        return warm_up(self, grid)

    # -- request intake -------------------------------------------------------
    def submit(self, graph: Union[BipartiteCSR, DeviceCSR], *,
               config: Optional[MatcherConfig] = None,
               warm_start: Optional[str] = None) -> Future:
        """Admit ``graph`` and enqueue it; returns a Future[MatchResult].

        Raises :class:`OversizeGraphError` synchronously when the graph fits
        no declared bucket and the bucketizer's policy is ``"reject"``;
        raises :class:`ServiceClosedError` after :meth:`close`.
        """
        cfg = config if config is not None else self.config
        ws = warm_start if warm_start is not None else self.warm_start
        self.matcher(cfg, ws)      # fail fast here, not on the flush thread
        try:
            # dirop configs solve through the CSC mirror: admission attaches
            # it so the dispatched pytree matches what warmup compiled
            adm = self.bucketizer.admit(graph, csc=cfg.dirop or None)
        except OversizeGraphError:
            self.metrics.record_reject()
            raise
        fut: Future = Future()
        req = _Request(admission=adm, config=cfg, warm_start=ws,
                       future=fut, submitted_at=time.perf_counter())
        with self._cond:
            if self._stop:
                raise ServiceClosedError("submit() on a closed service")
            self.metrics.record_submit(adm.nnz, adm.graph.nnz_pad)
            if adm.route == "sharded":
                self._sharded_q.append(req)
            else:
                flush = self._batcher.add((adm.bucket, cfg, ws), req,
                                          req.submitted_at)
                if flush is not None:
                    self._ready.append(flush)
            self._cond.notify_all()
        return fut

    # -- lifecycle ------------------------------------------------------------
    def flush(self) -> None:
        """Force-flush every queued request now (non-blocking)."""
        with self._cond:
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()

    def drain(self) -> None:
        """Flush everything and block until all accepted requests resolved."""
        with self._cond:
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()
            while (self._ready or self._sharded_q or self._inflight
                   or self._batcher.pending):
                self._cond.wait(0.01)
                self._ready.extend(self._batcher.drain())

    def close(self) -> None:
        """Graceful shutdown: drain, then stop the flush thread."""
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()
        self._thread.join(timeout=120)

    def __enter__(self) -> "MatchingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the flush thread -----------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                while True:
                    now = time.perf_counter()
                    self._ready.extend(self._batcher.due(now))
                    if self._ready or self._sharded_q:
                        break
                    if self._stop:
                        if self._batcher.pending:
                            self._ready.extend(self._batcher.drain())
                            continue
                        return
                    deadline = self._batcher.next_deadline()
                    timeout = (None if deadline is None
                               else max(0.0, deadline - now))
                    self._cond.wait(timeout)
                ready, self._ready = self._ready, []
                sharded, self._sharded_q = self._sharded_q, []
                self._inflight += len(ready) + len(sharded)
            try:
                # per-item guards: an exception must resolve the affected
                # futures, never kill the flush thread (which would strand
                # every later request)
                for flush in ready:
                    try:
                        self._dispatch(flush)
                    except Exception as e:
                        self._fail([q.payload for q in flush.items], e)
                for req in sharded:
                    try:
                        self._dispatch_sharded(req)
                    except Exception as e:
                        self._fail([req], e)
            finally:
                with self._cond:
                    self._inflight -= len(ready) + len(sharded)
                    self._cond.notify_all()

    def _fail(self, reqs: List[_Request], exc: BaseException) -> None:
        """Resolve still-pending futures with ``exc`` (dispatch escaped)."""
        undone = [r for r in reqs if not r.future.done()]
        self.metrics.record_failed(len(undone))
        for r in undone:
            r.future.set_exception(exc)

    def _dispatch(self, flush: Flush) -> None:
        """ONE device dispatch for a flushed bucket: stack + run_many."""
        bucket, cfg, ws = flush.key
        # claim the futures: once RUNNING a caller-side cancel() can no
        # longer race our set_result; already-cancelled requests drop out
        reqs: List[_Request] = [q.payload for q in flush.items
                                if q.payload.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        t0 = time.perf_counter()
        graphs = [r.admission.graph for r in reqs]
        padded = batch_bucket(len(graphs), self._batcher.max_batch)
        graphs = graphs + [graphs[0]] * (padded - len(graphs))  # inert lanes
        info0 = compile_cache_thread_info()
        try:
            batch = DeviceCSR.stack(graphs)
            out = self.matcher(cfg, ws).run_many(batch)
            jax.block_until_ready(out.cmatch)
        except Exception as e:
            self.metrics.record_failed(len(reqs))
            for r in reqs:
                r.future.set_exception(e)
            return
        done = time.perf_counter()
        info1 = compile_cache_thread_info()
        self.metrics.record_flush(
            flush.reason, real=len(reqs), padded=padded,
            hits=info1["hits"] - info0["hits"],
            misses=info1["misses"] - info0["misses"])
        for i, r in enumerate(reqs):
            state = jax.tree.map(lambda x: x[i], out)
            qw = t0 - r.submitted_at
            lat = done - r.submitted_at
            self.metrics.record_done(qw, lat)
            r.future.set_result(MatchResult(
                state=state, stats=MatchStats.of(state, cfg.name),
                bucket=bucket, route="bucket",
                nc=r.admission.nc, nr=r.admission.nr,
                batch_size=len(reqs), queue_wait_s=qw, latency_s=lat))

    def _dispatch_sharded(self, req: _Request) -> None:
        """Oversize lane: one edge-partitioned ShardedMatcher run."""
        if not req.future.set_running_or_notify_cancel():
            return                                 # cancelled while queued
        t0 = time.perf_counter()
        key = (req.config, req.warm_start)
        m = self._sharded.get(key)
        if m is None:
            m = self._sharded[key] = ShardedMatcher(
                self.mesh, self.shard_axis, req.config, req.warm_start)
        try:
            graph = req.admission.graph.shard(self.mesh, self.shard_axis)
            out = m.run(graph)
            jax.block_until_ready(out.cmatch)
        except Exception as e:
            self.metrics.record_failed()
            req.future.set_exception(e)
            return
        done = time.perf_counter()
        qw = t0 - req.submitted_at
        lat = done - req.submitted_at
        self.metrics.record_sharded()
        self.metrics.record_done(qw, lat)
        req.future.set_result(MatchResult(
            state=out, stats=m.stats(out), bucket=None, route="sharded",
            nc=req.admission.nc, nr=req.admission.nr,
            batch_size=1, queue_wait_s=qw, latency_s=lat))
