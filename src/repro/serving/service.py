"""``MatchingService``: the online facade over the device-resident matcher.

Request path::

    submit(graph) ── Bucketizer.admit ──► per-(bucket, config, warm-start)
        │            (validate first)     queue in the MicroBatcher
        └─► Future[MatchResult]                 │ full / deadline / drain
                                                ▼
                        flush thread: DeviceCSR.stack + ONE
                        Matcher.run_many dispatch per flush,
                        then per-request MatchState slicing

``submit`` is non-blocking and returns a ``concurrent.futures.Future``; a
single background thread owns every device dispatch (batched buckets and the
oversize sharded lane), so callers never contend on the accelerator.  Flushed
batches are padded to the :func:`batch_ladder` rung with copies of the first
graph (inert lanes, results discarded) so the compile cache sees only the
batch shapes AOT warmup declared.  ``drain()`` flushes everything queued and
blocks until every accepted request resolved; ``close()`` drains and stops
the thread (also via the context-manager protocol).

Fault tolerance (the "failure model & degradation ladder" section of
``docs/architecture.md``):

* **validate** — admission structurally checks every graph
  (``Bucketizer(validate=True)``, on by default for service-built
  bucketizers) so garbage never reaches a kernel;
* **quarantine** — a failed batched dispatch is retried by *bisection*:
  split, re-dispatch the halves with bounded exponential backoff, recurse;
  innocent co-batched requests succeed and the isolated poisoned request
  alone fails with the real error plus a ``repro-serving-quarantine/1``
  artifact (``quarantine_dir``);
* **shed** — ``submit(deadline_s=...)`` requests that expire while queued
  resolve with :class:`DeadlineExceededError` at flush time instead of
  occupying vmap lanes, and a bounded admission queue (``max_queue``) sheds
  under overload per ``shed_policy``: ``"reject-newest"`` refuses the
  incoming submit with :class:`QueueFullError` (the backpressure signal),
  ``"reject-oldest"`` admits it and evicts the longest-waiting queued
  request with :class:`SheddedError`;
* **degrade** — a ``MatcherConfig(max_phases=k, degrade_maximal=True)``
  budget makes the solve return a valid *maximal* matching with
  ``MatchResult.certified == False`` when the budget truncates it;
* **restart** — a supervisor watches the flush thread, and on death (a
  crash no ``except Exception`` guard can see) fails the in-flight futures
  with :class:`FlushThreadDiedError`, restarts the thread, and the service
  keeps serving.  :class:`~repro.serving.faults.FaultInjector` drives every
  one of these paths deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.csr import BipartiteCSR
from repro.matching import (DeviceCSR, GraphValidationError, Matcher,
                            MatcherConfig, MatchState, MatchStats,
                            ShardedMatcher)
from repro.matching.cache import compile_cache_thread_info

from .bucketizer import (Admission, Bucketizer, OversizeGraphError,
                         SizeBucket)
from .faults import FaultInjector, FlushThreadDeath
from .metrics import ServiceMetrics
from .scheduler import Flush, MicroBatcher, batch_bucket

QUARANTINE_SCHEMA = "repro-serving-quarantine/1"


class ServiceClosedError(RuntimeError):
    """submit() after close(), or a request stranded by shutdown."""


class QueueFullError(RuntimeError):
    """Backpressure: the bounded admission queue is full and the shed policy
    is ``"reject-newest"`` — the caller should retry later or back off."""

    def __init__(self, depth: int, max_queue: int):
        self.depth, self.max_queue = depth, max_queue
        super().__init__(
            f"admission queue full ({depth}/{max_queue}); backpressure — "
            "retry later (shed_policy='reject-newest')")


class SheddedError(RuntimeError):
    """This queued request was evicted to admit a newer one
    (``shed_policy="reject-oldest"`` under overload)."""


class DeadlineExceededError(TimeoutError):
    """The request's ``deadline_s`` expired before its flush dispatched."""


class FlushThreadDiedError(RuntimeError):
    """The flush thread crashed while this request was in flight; the
    supervisor failed it and restarted the thread (resubmitting is safe)."""


@dataclasses.dataclass(frozen=True)
class MatchResult:
    """One resolved request: the sliced device state + serving accounting."""

    state: MatchState                 # bucket-shaped (padded) matching state
    stats: MatchStats
    bucket: Optional[SizeBucket]      # None on the sharded route
    route: str                        # "bucket" | "sharded"
    nc: int                           # true submitted sizes
    nr: int
    batch_size: int                   # real requests in the flush served with
    queue_wait_s: float
    latency_s: float

    @property
    def cardinality(self) -> int:
        """Matched pairs (host sync; padding vertices are isolated, so this
        equals the true graph's maximum matching cardinality)."""
        return int(self.stats.cardinality)

    @property
    def certified(self) -> bool:
        """True iff the solver proved the matching maximum (Berge); False
        when a ``MatcherConfig.max_phases`` budget truncated the solve —
        the matching is still valid (and maximal under
        ``degrade_maximal=True``), just possibly sub-maximum."""
        return bool(self.stats.certified)

    def matching(self):
        """(cmatch, rmatch) as true-size numpy arrays (bucket padding cut)."""
        cm, rm = self.state.to_host()
        return cm[: self.nc], rm[: self.nr]


@dataclasses.dataclass
class _Request:
    admission: Admission
    config: MatcherConfig
    warm_start: str
    future: Future
    submitted_at: float
    deadline: Optional[float] = None  # absolute perf_counter() time
    tag: Optional[str] = None


class MatchingService:
    """Accepts concurrent matching requests, serves them micro-batched.

    >>> svc = MatchingService(bucketizer=Bucketizer(buckets), max_batch=8)
    >>> svc.warm_up()                        # AOT: first dispatch = cache hit
    >>> fut = svc.submit(host_graph)         # non-blocking
    >>> fut.result().cardinality

    Overload/fault knobs (all optional; see the module docstring):
    ``max_queue`` bounds queued-but-undispatched requests; ``shed_policy``
    picks who pays when it overflows; ``dispatch_retries`` /
    ``retry_backoff_s`` tune the bisection retry; ``quarantine_dir`` keeps
    a JSON reproducer per quarantined request; ``faults`` installs a
    :class:`~repro.serving.faults.FaultInjector`; ``supervise`` (default
    on) arms the flush-thread watchdog.
    """

    def __init__(self, bucketizer: Optional[Bucketizer] = None,
                 config: MatcherConfig = MatcherConfig(),
                 warm_start: str = "cheap",
                 max_batch: int = 8, max_delay_ms: float = 2.0,
                 mesh=None, shard_axis: str = "data",
                 adaptive: bool = True,
                 metrics: Optional[ServiceMetrics] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject-newest",
                 dispatch_retries: int = 1,
                 retry_backoff_s: float = 0.002,
                 quarantine_dir: Optional[str] = None,
                 faults: Optional[FaultInjector] = None,
                 supervise: bool = True,
                 supervisor_interval_s: float = 0.05):
        if bucketizer is None:
            bucketizer = Bucketizer(
                oversize="shard" if mesh is not None else "reject",
                validate=True)
        assert bucketizer.oversize != "shard" or mesh is not None, \
            "oversize='shard' needs a mesh to shard over"
        assert shed_policy in ("reject-newest", "reject-oldest"), shed_policy
        assert max_queue is None or max_queue >= 1, max_queue
        assert dispatch_retries >= 0 and retry_backoff_s >= 0
        self.bucketizer = bucketizer
        self.config = config
        self.warm_start = warm_start
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.dispatch_retries = dispatch_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine_dir = quarantine_dir
        self.faults = faults
        self._batcher = MicroBatcher(max_batch=max_batch,
                                     max_delay_s=max_delay_ms / 1e3,
                                     adaptive=adaptive)
        self._matchers: Dict[Tuple[MatcherConfig, str], Matcher] = {}
        self._sharded: Dict[Tuple[MatcherConfig, str], ShardedMatcher] = {}
        self.matcher()     # validate the default config/warm start eagerly
        self._cond = threading.Condition()
        self._ready: List[Flush] = []
        self._sharded_q: List[_Request] = []
        self._taken: List[_Request] = []   # in flight on the flush thread
        self._stop = False
        self._thread = self._start_flush_thread()
        self._supervisor: Optional[threading.Thread] = None
        if supervise:
            self._supervisor = threading.Thread(
                target=self._supervise, args=(supervisor_interval_s,),
                name="matching-service-supervisor", daemon=True)
            self._supervisor.start()

    def _start_flush_thread(self) -> threading.Thread:
        t = threading.Thread(target=self._loop,
                             name="matching-service-flush", daemon=True)
        t.start()
        return t

    # -- matcher registry (shared with warmup so cache keys line up) ---------
    @property
    def max_batch(self) -> int:
        return self._batcher.max_batch

    @property
    def queue_depth(self) -> int:
        """Queued-but-undispatched requests (the bounded-admission gauge)."""
        with self._cond:
            return self._queue_depth_locked()

    def _queue_depth_locked(self) -> int:
        """Everything accepted but not yet claimed by the flush thread:
        accumulating in the batcher, staged in ready flushes, or waiting in
        the sharded lane.  In-flight (claimed) requests are not queue."""
        return (self._batcher.pending + len(self._sharded_q)
                + sum(len(f.items) for f in self._ready))

    def matcher(self, config: Optional[MatcherConfig] = None,
                warm_start: Optional[str] = None) -> Matcher:
        cfg = config if config is not None else self.config
        ws = warm_start if warm_start is not None else self.warm_start
        if cfg.adaptive_frontier:
            # run_many (the only dispatch path here) refuses this config;
            # surface that in the caller's thread, not on the flush thread
            # after the batching delay (dirop is the batch-safe variant)
            raise ValueError(
                "adaptive_frontier cannot be served (Matcher.run_many "
                "refuses it under vmap); use MatcherConfig(dirop=True)")
        key = (cfg, ws)
        m = self._matchers.get(key)
        if m is None:
            m = self._matchers[key] = Matcher(cfg, ws)
        return m

    def warm_up(self, grid=None):
        """AOT-compile the declared grid (see :mod:`repro.serving.warmup`)."""
        from .warmup import warm_up
        return warm_up(self, grid)

    # -- request intake -------------------------------------------------------
    def submit(self, graph: Union[BipartiteCSR, DeviceCSR], *,
               config: Optional[MatcherConfig] = None,
               warm_start: Optional[str] = None,
               deadline_s: Optional[float] = None,
               tag: Optional[str] = None) -> Future:
        """Admit ``graph`` and enqueue it; returns a Future[MatchResult].

        ``deadline_s`` bounds the time from submit to dispatch: a request
        still queued when it expires is shed at flush time and its future
        resolves with :class:`DeadlineExceededError`.  ``tag`` labels the
        request in quarantine artifacts (and is what
        :meth:`FaultInjector.poison` matches on).

        Raises :class:`OversizeGraphError` /
        :class:`~repro.matching.GraphValidationError` synchronously on
        admission failure, :class:`QueueFullError` under backpressure
        (``shed_policy="reject-newest"``), and :class:`ServiceClosedError`
        after :meth:`close`.
        """
        cfg = config if config is not None else self.config
        ws = warm_start if warm_start is not None else self.warm_start
        self.matcher(cfg, ws)      # fail fast here, not on the flush thread
        try:
            # dirop configs solve through the CSC mirror: admission attaches
            # it so the dispatched pytree matches what warmup compiled
            adm = self.bucketizer.admit(graph, csc=cfg.dirop or None)
        except (OversizeGraphError, GraphValidationError):
            self.metrics.record_reject()
            raise
        now = time.perf_counter()
        fut: Future = Future()
        req = _Request(admission=adm, config=cfg, warm_start=ws,
                       future=fut, submitted_at=now,
                       deadline=(None if deadline_s is None
                                 else now + deadline_s),
                       tag=tag)
        shed: Optional[_Request] = None
        with self._cond:
            if self._stop:
                raise ServiceClosedError("submit() on a closed service")
            depth = self._queue_depth_locked()
            if self.max_queue is not None and depth >= self.max_queue:
                if self.shed_policy == "reject-newest":
                    self.metrics.record_shed("reject-newest")
                    raise QueueFullError(depth, self.max_queue)
                shed = self._evict_oldest_locked()
            self.metrics.record_submit(adm.nnz, adm.graph.nnz_pad)
            if adm.route == "sharded":
                self._sharded_q.append(req)
            else:
                flush = self._batcher.add((adm.bucket, cfg, ws), req,
                                          req.submitted_at)
                if flush is not None:
                    self._ready.append(flush)
            self._cond.notify_all()
        if shed is not None:
            # resolve OUTSIDE the lock: done-callbacks may re-enter submit
            self.metrics.record_shed("reject-oldest")
            if not shed.future.cancelled():
                shed.future.set_exception(SheddedError(
                    "shed from a full admission queue to admit a newer "
                    "request (shed_policy='reject-oldest')"))
        return fut

    def _evict_oldest_locked(self) -> Optional[_Request]:
        """Pop the longest-queued request — whether still accumulating in
        the batcher, already staged in a ready flush, or in the sharded
        lane — so ``reject-oldest`` really evicts the globally oldest."""
        best = None                       # (enqueued_at, kind, ready_index)
        bt = self._batcher.oldest_enqueued_at()
        if bt is not None:
            best = (bt, "batcher", -1)
        if self._sharded_q:
            t = self._sharded_q[0].submitted_at
            if best is None or t < best[0]:
                best = (t, "sharded", -1)
        for i, f in enumerate(self._ready):
            t = f.items[0].enqueued_at   # items keep enqueue order
            if best is None or t < best[0]:
                best = (t, "ready", i)
        if best is None:
            return None
        _, kind, i = best
        if kind == "batcher":
            q = self._batcher.evict_oldest()
            return q.payload if q is not None else None
        if kind == "sharded":
            return self._sharded_q.pop(0)
        f = self._ready[i]
        victim, rest = f.items[0], f.items[1:]
        if rest:
            self._ready[i] = dataclasses.replace(f, items=rest)
        else:
            del self._ready[i]
        return victim.payload

    # -- lifecycle ------------------------------------------------------------
    def flush(self) -> None:
        """Force-flush every queued request now (non-blocking)."""
        with self._cond:
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()

    def drain(self) -> None:
        """Flush everything and block until all accepted requests resolved."""
        with self._cond:
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()
            while (self._ready or self._sharded_q or self._taken
                   or self._batcher.pending):
                self._cond.wait(0.01)
                self._ready.extend(self._batcher.drain())

    def close(self) -> None:
        """Graceful shutdown: drain, stop the flush thread — and never
        strand a future: anything still pending after the join window (a
        hung or dead thread) fails with :class:`ServiceClosedError`."""
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._ready.extend(self._batcher.drain())
            self._cond.notify_all()
        self._thread.join(timeout=120)
        stranded: List[_Request] = []
        with self._cond:
            for flush in self._ready:
                stranded.extend(q.payload for q in flush.items)
            self._ready = []
            stranded.extend(self._sharded_q)
            self._sharded_q = []
            stranded.extend(self._taken)
            self._taken = []
            stranded.extend(q.payload
                            for f in self._batcher.drain() for q in f.items)
            self._cond.notify_all()
        still_alive = self._thread.is_alive()
        undone = [r for r in stranded if not r.future.done()]
        if undone:
            self.metrics.record_failed(len(undone))
            why = ("flush thread did not exit within the close() join "
                   "window" if still_alive else
                   "service closed with the request unresolved")
            for r in undone:
                r.future.set_exception(ServiceClosedError(why))

    def __enter__(self) -> "MatchingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the flush thread -----------------------------------------------------
    def _loop(self) -> None:
        try:
            self._loop_impl()
        except FlushThreadDeath:
            # injected crash: die without the default excepthook traceback —
            # the unresolved in-flight set is already parked in _taken and
            # recovery (fail over + restart) belongs to the supervisor
            return

    def _loop_impl(self) -> None:
        while True:
            with self._cond:
                while True:
                    now = time.perf_counter()
                    self._ready.extend(self._batcher.due(now))
                    if self._ready or self._sharded_q:
                        break
                    if self._stop:
                        if self._batcher.pending:
                            self._ready.extend(self._batcher.drain())
                            continue
                        return
                    deadline = self._batcher.next_deadline()
                    timeout = (None if deadline is None
                               else max(0.0, deadline - now))
                    self._cond.wait(timeout)
                ready, self._ready = self._ready, []
                sharded, self._sharded_q = self._sharded_q, []
                self._taken.extend(q.payload for f in ready
                                   for q in f.items)
                self._taken.extend(sharded)
            try:
                # per-item guards: an exception must resolve the affected
                # futures, never kill the flush thread (which would strand
                # every later request).  FlushThreadDeath is a
                # BaseException precisely so it is NOT survivable here.
                for flush in ready:
                    try:
                        self._dispatch(flush)
                    except Exception as e:
                        self._fail([q.payload for q in flush.items], e)
                for req in sharded:
                    try:
                        self._dispatch_sharded(req)
                    except Exception as e:
                        self._fail([req], e)
            except BaseException:
                # crash unwind (FlushThreadDeath): leave the unresolved
                # in-flight set in _taken — it is exactly what the
                # supervisor fails over before restarting the thread
                with self._cond:
                    self._taken = [r for r in self._taken
                                   if not r.future.done()]
                    self._cond.notify_all()
                raise
            # clean pass: every request taken this round was resolved by
            # its dispatch guard, so this empties _taken; anything left
            # was dropped by a dispatch bug — fail loudly, never strand
            with self._cond:
                leak = [r for r in self._taken if not r.future.done()]
                self._taken = []
                self._cond.notify_all()
            for r in leak:
                self._fail([r], RuntimeError(
                    "request dropped by dispatch without resolution"))

    # -- the supervisor -------------------------------------------------------
    def _supervise(self, interval_s: float) -> None:
        """Watchdog: detect a dead flush thread, fail its in-flight futures,
        restart it.  Exits when the service closes."""
        while True:
            time.sleep(interval_s)
            with self._cond:
                if self._stop:
                    return
                if self._thread.is_alive():
                    continue
                # thread died outside close(): take over its in-flight set
                dead, self._taken = self._taken, []
            undone = [r for r in dead if not r.future.done()]
            self.metrics.record_failed(len(undone))
            for r in undone:
                r.future.set_exception(FlushThreadDiedError(
                    "the flush thread died while this request was in "
                    "flight; it has been restarted — resubmit"))
            with self._cond:
                if self._stop:
                    return
                self._thread = self._start_flush_thread()
                self.metrics.record_restart()
                self._cond.notify_all()

    def _fail(self, reqs: List[_Request], exc: BaseException) -> None:
        """Resolve still-pending futures with ``exc`` (dispatch escaped)."""
        undone = [r for r in reqs if not r.future.done()]
        self.metrics.record_failed(len(undone))
        for r in undone:
            r.future.set_exception(exc)

    # -- dispatch -------------------------------------------------------------
    def _claim(self, reqs: List[_Request]) -> List[_Request]:
        """Claim futures and shed expired ones; returns the live set.

        ``set_running_or_notify_cancel`` wins the race against caller-side
        ``cancel()``; a request whose deadline passed while queued is shed
        here — at flush time, before it can occupy a vmap lane."""
        now = time.perf_counter()
        live: List[_Request] = []
        for r in reqs:
            if not r.future.set_running_or_notify_cancel():
                self.metrics.record_cancelled()
                continue
            if r.deadline is not None and now >= r.deadline:
                self.metrics.record_deadline_miss()
                r.future.set_exception(DeadlineExceededError(
                    f"deadline expired {now - r.deadline:.4f}s before "
                    "dispatch (queued too long; see shed/deadline metrics)"))
                continue
            live.append(r)
        return live

    def _run_batch(self, reqs: List[_Request], cfg: MatcherConfig,
                   ws: str) -> Tuple[MatchState, int, float, float]:
        """ONE stacked run_many over ``reqs`` -> (out, padded, t0, done)."""
        t0 = time.perf_counter()
        if self.faults is not None:
            self.faults.before_dispatch(reqs)
        graphs = [r.admission.graph for r in reqs]
        padded = batch_bucket(len(graphs), self._batcher.max_batch)
        graphs = graphs + [graphs[0]] * (padded - len(graphs))  # inert lanes
        batch = DeviceCSR.stack(graphs)
        out = self.matcher(cfg, ws).run_many(batch)
        jax.block_until_ready(out.cmatch)
        return out, padded, t0, time.perf_counter()

    def _dispatch(self, flush: Flush) -> None:
        """One flushed bucket: claim, shed expired, then batch-dispatch
        with bisection recovery."""
        bucket, cfg, ws = flush.key
        reqs = self._claim([q.payload for q in flush.items])
        if not reqs:
            return
        self._dispatch_reqs(reqs, bucket, cfg, ws, flush.reason)

    def _dispatch_reqs(self, reqs: List[_Request], bucket, cfg, ws,
                       reason: str, depth: int = 0) -> None:
        """Dispatch ``reqs`` as one batch; on failure, isolate the poison.

        A multi-request batch that fails is split in half and each half
        re-dispatched after a bounded exponential backoff — innocent
        co-batched requests land in an all-good half within O(log batch)
        re-dispatches and succeed.  A singleton that still fails after
        ``dispatch_retries`` retries is the isolated poisoned request: its
        future gets the real error and a quarantine artifact is dumped.
        """
        retries = self.dispatch_retries if len(reqs) == 1 else 0
        for attempt in range(retries + 1):
            if depth or attempt:
                time.sleep(min(0.2, self.retry_backoff_s
                               * (2 ** (depth + attempt - 1))))
            info0 = compile_cache_thread_info()
            try:
                out, padded, t0, done = self._run_batch(reqs, cfg, ws)
            except FlushThreadDeath:
                raise                       # a crash is not a request error
            except Exception as e:
                if attempt < retries:
                    continue
                if len(reqs) == 1:
                    self._quarantine(reqs[0], e)
                    return
                mid = len(reqs) // 2
                self._dispatch_reqs(reqs[:mid], bucket, cfg, ws, reason,
                                    depth + 1)
                self._dispatch_reqs(reqs[mid:], bucket, cfg, ws, reason,
                                    depth + 1)
                return
            break
        info1 = compile_cache_thread_info()
        self._resolve_batch(reqs, out, padded, bucket, cfg, reason, t0, done,
                            hits=info1["hits"] - info0["hits"],
                            misses=info1["misses"] - info0["misses"])

    def _resolve_batch(self, reqs, out, padded, bucket, cfg, reason,
                       t0: float, done: float, hits: int = 0,
                       misses: int = 0) -> None:
        self.metrics.record_flush(reason, real=len(reqs), padded=padded,
                                  hits=hits, misses=misses)
        for i, r in enumerate(reqs):
            state = jax.tree.map(lambda x: x[i], out)
            qw = t0 - r.submitted_at
            lat = done - r.submitted_at
            self.metrics.record_done(qw, lat)
            r.future.set_result(MatchResult(
                state=state, stats=MatchStats.of(state, cfg.name),
                bucket=bucket, route="bucket",
                nc=r.admission.nc, nr=r.admission.nr,
                batch_size=len(reqs), queue_wait_s=qw, latency_s=lat))

    def _quarantine(self, req: _Request, exc: Exception) -> None:
        """The isolated poisoned request: fail it with the real error and
        keep a ``repro-serving-quarantine/1`` reproducer (mirroring the
        corpus harness's ddmin artifacts)."""
        self.metrics.record_quarantined()
        self.metrics.record_failed()
        artifact = ""
        if self.quarantine_dir:
            try:
                artifact = self._dump_quarantine(req, exc)
            except Exception:       # never let artifact IO mask the error
                artifact = ""
        exc.quarantine_artifact = artifact      # breadcrumb for the caller
        req.future.set_exception(exc)

    def _dump_quarantine(self, req: _Request, exc: Exception) -> str:
        os.makedirs(self.quarantine_dir, exist_ok=True)
        g = req.admission.graph
        nnz = int(g.nnz)
        name = req.tag or f"req_{id(req):x}"
        out = os.path.join(self.quarantine_dir, f"quarantine_{name}.json")
        with open(out, "w") as f:
            json.dump({
                "schema": QUARANTINE_SCHEMA,
                "tag": req.tag,
                "error": f"{type(exc).__name__}: {exc}",
                "config": dataclasses.asdict(req.config),
                "warm_start": req.warm_start,
                "nc": req.admission.nc, "nr": req.admission.nr, "nnz": nnz,
                "bucket": (list(req.admission.bucket.key)
                           if req.admission.bucket else None),
                "edges": np.stack([np.asarray(g.ecol)[:nnz],
                                   np.asarray(g.cadj)[:nnz]],
                                  axis=1).tolist(),
            }, f, indent=2, sort_keys=True)
        return out

    def _dispatch_sharded(self, req: _Request) -> None:
        """Oversize lane: one edge-partitioned ShardedMatcher run."""
        reqs = self._claim([req])
        if not reqs:
            return
        t0 = time.perf_counter()
        key = (req.config, req.warm_start)
        m = self._sharded.get(key)
        if m is None:
            m = self._sharded[key] = ShardedMatcher(
                self.mesh, self.shard_axis, req.config, req.warm_start)
        try:
            if self.faults is not None:
                self.faults.before_dispatch(reqs)
            graph = req.admission.graph.shard(self.mesh, self.shard_axis)
            out = m.run(graph)
            jax.block_until_ready(out.cmatch)
        except FlushThreadDeath:
            raise
        except Exception as e:
            self._quarantine(req, e)
            return
        done = time.perf_counter()
        qw = t0 - req.submitted_at
        lat = done - req.submitted_at
        self.metrics.record_sharded()
        self.metrics.record_done(qw, lat)
        req.future.set_result(MatchResult(
            state=out, stats=m.stats(out), bucket=None, route="sharded",
            nc=req.admission.nc, nr=req.admission.nr,
            batch_size=1, queue_wait_s=qw, latency_s=lat))
