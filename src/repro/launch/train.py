"""Fault-tolerant training driver.

``python -m repro.launch.train --arch <id> [--smoke] --steps N``

The loop is restart-safe: state lives in step-atomic checkpoints
(repro.ckpt); on start it resumes from the newest manifest; the data
pipeline is a pure function of (seed, step) so no data-state needs saving.
``--simulate-failure K`` aborts the process at step K (used by the FT test
to prove a restart continues bit-exactly).  ``--mesh dxm`` picks the device
mesh; on restart with a different mesh the checkpoint re-shards (elastic).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_NAMES, get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model, set_mesh
from repro.models.common import named_sharding
from repro.optim import OptConfig, adamw_init
from repro.train import build_train_step


def shardings_for(mesh, specs_tree, value_tree):
    return jax.tree.map(
        lambda s, v: named_sharding(mesh, s, v.shape), specs_tree, value_tree,
        is_leaf=lambda s: isinstance(s, P))


def run(arch: str, steps: int, smoke: bool, mesh_shape, batch: int,
        seq: int, ckpt_dir: str, simulate_failure: int = 0,
        microbatch: int = 0, log_every: int = 10, lr: float = 3e-4):
    mesh = jax.make_mesh(mesh_shape, ("data", "model")[: len(mesh_shape)]
                         if len(mesh_shape) > 1 else ("data",))
    logical = {"data": ("data",), "model": ("model",)
               if "model" in mesh.axis_names else ()}
    if "model" not in mesh.shape:
        logical["model"] = ()
    set_mesh(mesh, logical)

    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, specs = model.init(rng)
    opt_cfg = OptConfig(lr=lr, factored=cfg.params_count() > 60e9,
                        master_fp32=cfg.params_count() <= 60e9,
                        warmup=min(100, steps // 10 + 1))
    opt_state, ospecs = adamw_init(params, specs, opt_cfg)

    pshard = shardings_for(mesh, specs, params)
    oshard = shardings_for(mesh, ospecs, opt_state)
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)

    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        state, start = restore_checkpoint(
            ckpt_dir, {"params": params, "opt": opt_state}, mesh=mesh,
            sharding_tree={"params": pshard, "opt": oshard})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start}", flush=True)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    step_fn = jax.jit(
        build_train_step(model, opt_cfg, microbatch=microbatch),
        in_shardings=(pshard, oshard, None),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        np_batch = synthetic_batch(dcfg, step)
        batch_j = {k: jax.device_put(v) for k, v in np_batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_j)
        if simulate_failure and step + 1 == simulate_failure:
            # checkpoint then die hard: the restart path must resume
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state})
            print(f"[train] simulated failure at step {step + 1}", flush=True)
            os._exit(17)
        if (step + 1) % log_every == 0 or step + 1 == steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            dt = time.time() - t0
            print(f"[train] step {step + 1:5d} loss {loss:.4f} "
                  f"({dt / max(1, step + 1 - start):.2f}s/step)", flush=True)
        if ckpt_dir and ((step + 1) % 50 == 0 or step + 1 == steps):
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state})
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--simulate-failure", type=int, default=0)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    run(args.arch, args.steps, args.smoke, mesh_shape, args.batch, args.seq,
        args.ckpt_dir, args.simulate_failure, args.microbatch, lr=args.lr)


if __name__ == "__main__":
    main()
