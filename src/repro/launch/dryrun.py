import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step function (train_step / prefill /
serve_step) against ShapeDtypeStruct inputs on the production mesh, compiles
it, and records:

  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the optimized HLO text per collective op,

into experiments/dryrun/<arch>__<shape>__<mesh>.json, which
benchmarks/roofline.py turns into the roofline table
(docs/architecture.md, "LM-substrate notes").

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch h2o-danube-1.8b \
      --shape train_4k [--multi-pod] [--all] [--out experiments/dryrun]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import data_axis_size, logical_rules, make_production_mesh
from repro.models import build_model, resolve_spec, set_mesh
from repro.models.common import ModelConfig, named_sharding
from repro.optim import OptConfig, adamw_init
from repro.train import build_prefill_step, build_serve_step, build_train_step

HW = {  # TPU v5e-like, per chip (spec'd constants)
    "peak_flops": 197e12,        # bf16
    "hbm_gbs": 819e9,
    "ici_gbs": 50e9,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def abstract_init(model, rng):
    """Shapes of params + the (static) spec tree, without allocating."""
    box = {}

    def init_only(r):
        p, s = model.init(r)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(init_only, rng)
    return shapes, box["specs"]


def abstract_opt(params_shapes, specs, opt_cfg):
    box = {}

    def init_only(p):
        st, ss = adamw_init(p, specs, opt_cfg)
        box["specs"] = ss
        return st

    shapes = jax.eval_shape(init_only, params_shapes)
    return shapes, box["specs"]


def abstract_cache(model, batch, max_len, enc_len):
    box = {}

    def init_only():
        c, s = model.init_cache(batch, max_len, enc_len=enc_len)
        box["specs"] = s
        return c

    shapes = jax.eval_shape(init_only)
    return shapes, box["specs"]


def opt_config_for(cfg: ModelConfig) -> OptConfig:
    big = cfg.params_count() >= 60e9
    return OptConfig(factored=big, master_fp32=not big)


def batch_specs(mesh, batch_shapes) -> Dict[str, Any]:
    return jax.tree.map(
        lambda s: named_sharding(mesh, P("data"), s.shape), batch_shapes)


def model_flops(cfg: ModelConfig, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) reference FLOPs for the cell."""
    n = cfg.params_count()
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_nonemb = n - emb
    if cfg.family == "moe":
        # active = experts reduced to top_k (+ shared)
        mlp_all = n_nonemb
        gated = 3 if cfg.act in ("swiglu", "geglu") else 2
        expert_p = cfg.n_layers * cfg.n_experts * gated * cfg.d_model * cfg.d_ff
        active_exp = expert_p * (cfg.top_k / cfg.n_experts)
        n_active = n_nonemb - expert_p + active_exp
    else:
        n_active = n_nonemb
    # decode processes 1 token/step; train does fwd+bwd (3x fwd cost)
    tokens = shape.batch * (shape.seq if shape.kind in ("train", "prefill")
                            else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             cfg_overrides: Dict[str, Any] = None,
             tag: str = "") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh, logical_rules(multi_pod))
    cfg = get_config(arch, **(cfg_overrides or {}))
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "tag": tag,
    }
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return rec

    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    t0 = time.time()
    params_sh, specs = abstract_init(model, rng)
    pshard = jax.tree.map(
        lambda s, p: named_sharding(mesh, s, p.shape), specs, params_sh,
        is_leaf=lambda s: isinstance(s, P))
    binp = input_specs(cfg, shape)
    bshard = batch_specs(mesh, binp)

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        opt_sh, osspecs = abstract_opt(params_sh, specs, opt_cfg)
        oshard = jax.tree.map(
            lambda s, p: named_sharding(mesh, s, p.shape), osspecs, opt_sh,
            is_leaf=lambda s: isinstance(s, P))
        step = build_train_step(model, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1))
        lowered = jitted.lower(params_sh, opt_sh, binp)
    elif shape.kind == "prefill":
        step = build_prefill_step(model)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_sh, binp)
    else:  # decode
        enc_len = max(cfg.frontend_len, 1024) if cfg.enc_layers else 0
        cache_sh, cspecs = abstract_cache(model, shape.batch, shape.seq,
                                          enc_len)
        cshard = jax.tree.map(
            lambda s, c: named_sharding(mesh, s, c.shape), cspecs, cache_sh,
            is_leaf=lambda s: isinstance(s, P))
        step = build_serve_step(model)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, cshard, bshard["tokens"]),
            out_shardings=(None, cshard),
            donate_argnums=(1,))
        lowered = jitted.lower(params_sh, cache_sh, binp["tokens"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # loop-aware accounting: xla's HloCostAnalysis counts while bodies once,
    # which under-counts scanned layer stacks ~L-fold (see hlo_cost.py)
    hc = hlo_analyze(hlo)
    coll = {k: hc["collective_detail"].get(k, 0.0) for k in COLLECTIVES}
    coll["counts"] = {}

    ndev = 512 if multi_pod else 256
    flops = float(hc["flops"])
    bytes_acc = float(hc["bytes"])
    mf = model_flops(cfg, shape)
    # memory_analysis is per-device on this backend
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "devices": ndev,
        "flops_total": flops,
        "bytes_total": bytes_acc,
        "model_flops": mf,
        "collectives": coll,
        "unknown_while": hc["unknown_while"],
        "collective_top": [[k, v] for k, v in hc.get("collective_top", [])],
        "xla_cost_raw": {"flops": float(cost.get("flops", 0.0)),
                         "bytes": float(cost.get("bytes accessed", 0.0))},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "params": cfg.params_count(),
    })
    # roofline terms (seconds); cost_analysis flops/bytes are whole-program
    # (all devices execute the SPMD program; flops reported are per-program
    # which equals per-device under SPMD)
    rec["terms"] = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": bytes_acc / HW["hbm_gbs"],
        "collective_s": float(hc["collective_bytes"]) / HW["ici_gbs"],
    }
    rec["dominant"] = max(rec["terms"], key=rec["terms"].get)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{rec['mesh']}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=2, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="",
                    help="comma list: attn,moe,kv -> optimization flags")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = {}
    if "attn" in args.opt:
        overrides["opt_attn_layout"] = True
    if "moe" in args.opt:
        overrides["opt_moe_dispatch"] = True
    if "kv" in args.opt:
        overrides["opt_kv_quant"] = True

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, args.out,
                                   cfg_overrides=overrides, tag=args.tag)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if rec["status"] == "ok":
                    m = rec["memory"]
                    print(f"[{rec['mesh']}] {arch:28s} {shape:12s} OK "
                          f"compile={rec['compile_s']:6.1f}s "
                          f"peak/dev={m['peak_bytes']/2**30:6.2f}GiB "
                          f"dominant={rec['dominant']}", flush=True)
                elif rec["status"] == "skip":
                    print(f"[{rec['mesh']}] {arch:28s} {shape:12s} SKIP "
                          f"({rec['reason']})", flush=True)
                else:
                    print(f"[{rec['mesh']}] {arch:28s} {shape:12s} FAIL "
                          f"{rec['error']}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
