"""Production mesh definitions.

A function, not a module constant: importing this module never touches jax
device state.  Single pod = 16x16 (256 chips, v5e pod); multi-pod adds a
leading ``pod`` axis (2 pods = 512 chips).  The logical "data" axis used by
model/optimizer specs resolves to ("pod", "data") on the multi-pod mesh so
batch/FSDP sharding composes across pods (see models.common.set_mesh).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def logical_rules(multi_pod: bool) -> Dict[str, Tuple[str, ...]]:
    return {"data": ("pod", "data") if multi_pod else ("data",),
            "model": ("model",)}


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.shape:
        n *= mesh.shape["pod"]
    return n
