"""Open-loop synthetic traffic replay against the matching service.

Generates a mixed trace from the four generator families standing in for the
paper's UFL classes (random / Kronecker / grid / scaled-free), fires it at
the :class:`repro.serving.MatchingService` with Poisson (open-loop) arrivals
— the trace keeps its own pace whether or not the service keeps up, so
queueing shows up as latency exactly like production traffic — and prints
warmup, per-family, and service-level metrics.

    python -m repro.launch.serve_matching --smoke          # CI smoke
    python -m repro.launch.serve_matching --rate 500 --requests 256
    python -m repro.launch.serve_matching --smoke --chaos  # + fault drill

``--smoke`` shrinks the trace, asserts cardinality parity against a direct
``Matcher`` for every request, and (on a multi-device host) exercises the
oversize → ShardedMatcher admission route.  ``--chaos`` arms a seeded
:class:`repro.serving.FaultInjector` and, after the replay, runs a fault
drill: poisons one tagged request among innocents (asserting bisection
isolates exactly it), then kills the flush thread mid-batch (asserting the
supervisor fails the in-flight futures and restarts, and later submits are
served).  Exit status is non-zero if any fault-tolerance contract is
violated.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.csr import BipartiteCSR
from repro.graphs import (grid_graph, kron_graph, random_bipartite,
                          scaled_free)
from repro.matching import DeviceCSR, Matcher, MatcherConfig
from repro.serving import (Bucketizer, FaultInjector, FlushThreadDiedError,
                           MatchingService, PoisonedGraphFault, SizeBucket,
                           ladder, percentile)

FAMILIES: Dict[str, Callable[[int, int], BipartiteCSR]] = {
    # name -> (size hint n, seed) -> instance
    "random": lambda n, s: random_bipartite(n, n - n // 8, 3.0, seed=s),
    "kron": lambda n, s: kron_graph(max(4, int(np.log2(max(n, 16)))),
                                    6, seed=s),
    "grid": lambda n, s: grid_graph(max(4, int(np.sqrt(n)))),
    "free": lambda n, s: scaled_free(n, n, 4.0, seed=s),
}


def build_trace(n_requests: int, n_hint: int, seed: int
                ) -> List[Tuple[str, BipartiteCSR]]:
    """Round-robin over the families with varying seeds (mixed workload)."""
    names = list(FAMILIES)
    return [(names[i % len(names)],
             FAMILIES[names[i % len(names)]](n_hint, seed + i))
            for i in range(n_requests)]


def replay(service: MatchingService, trace, rate_rps: float, seed: int):
    """Open-loop submit: arrival i fires at its Poisson timestamp."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=len(trace)))
    t0 = time.perf_counter()
    futures = []
    for (family, g), t_arr in zip(trace, arrivals):
        lag = t0 + t_arr - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        futures.append((family, g, service.submit(g)))
    return futures


def chaos_drill(service: MatchingService, injector: FaultInjector,
                size: int, seed: int) -> int:
    """The two headline fault drills; returns the number of contract
    violations (0 = the failure model held)."""
    failures = 0
    graphs = [random_bipartite(size, size - size // 8, 3.0, seed=seed + 7000 + i)
              for i in range(6)]

    # 1. poisoned batch: bisection must isolate exactly the tagged request
    injector.poison("bad")
    futs = [service.submit(g, tag="bad" if i == 2 else None)
            for i, g in enumerate(graphs)]
    service.drain()
    for i, fut in enumerate(futs):
        exc = fut.exception(timeout=60)
        if i == 2 and not isinstance(exc, PoisonedGraphFault):
            print(f"[chaos] poisoned request resolved {exc!r}, "
                  "expected PoisonedGraphFault")
            failures += 1
        elif i != 2 and exc is not None:
            print(f"[chaos] innocent co-batched request {i} failed: {exc!r}")
            failures += 1
    injector.cure("bad")

    # 2. flush-thread death: supervisor fails in-flight, restarts, serves
    injector.kill_thread_after(0)       # the very next dispatch dies
    futs = [service.submit(g) for g in graphs[:4]]
    service.flush()
    died = sum(isinstance(f.exception(timeout=60), FlushThreadDiedError)
               for f in futs)
    res = service.submit(graphs[0]).result(timeout=60)   # post-restart
    snap = service.metrics.snapshot()
    print(f"[chaos] quarantined={snap['quarantined']} "
          f"restarts={snap['restarts']} in-flight-failed={died} "
          f"post-restart |M|={res.cardinality}")
    if snap["quarantined"] < 1:
        print("[chaos] FAIL: poisoned request was not quarantined")
        failures += 1
    if snap["restarts"] < 1 or died < 1:
        print("[chaos] FAIL: supervisor did not fail over + restart")
        failures += 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="replay synthetic open-loop traffic at the service")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace + parity assertions (CI)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate", type=float, default=300.0,
                    help="offered load, requests/second (open loop)")
    ap.add_argument("--size", type=int, default=1024,
                    help="family size hint (vertices)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--delay-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", action="store_true",
                    help="arm a FaultInjector and run the fault drill "
                         "(poison isolation + flush-thread death/restart) "
                         "after the replay")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FaultInjector seed (deterministic fault schedule)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests, args.rate, args.size = 12, 500.0, 224
        buckets = (SizeBucket(256, 256, 2048),)
        args.max_batch = 4
    else:
        buckets = ladder(max_vertices=max(256, args.size * 2))

    import jax
    mesh = None
    if jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    injector = FaultInjector(seed=args.chaos_seed) if args.chaos else None
    service = MatchingService(
        bucketizer=Bucketizer(buckets,
                              oversize="shard" if mesh else "reject",
                              validate=True),
        config=MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct"),
        warm_start="cheap", max_batch=args.max_batch,
        max_delay_ms=args.delay_ms, mesh=mesh, faults=injector)
    report = service.warm_up()
    print(f"[serve_matching] {report}")

    trace = build_trace(args.requests, args.size, args.seed)
    futures = replay(service, trace, args.rate, args.seed)
    results = [(fam, g, fut.result(timeout=300)) for fam, g, fut in futures]
    service.drain()

    failures = 0
    per_family: Dict[str, List[float]] = {}
    for fam, g, res in results:
        per_family.setdefault(fam, []).append(res.latency_s)
        if args.smoke:
            direct = Matcher(service.config, service.warm_start).run(
                DeviceCSR.from_host(g).bucketed())
            if res.cardinality != int(direct.cardinality):
                print(f"[serve_matching] PARITY FAIL {fam}: "
                      f"{res.cardinality} != {int(direct.cardinality)}")
                failures += 1
    for fam, lats in sorted(per_family.items()):
        print(f"[serve_matching] {fam:>7}: {len(lats):3d} req, "
              f"p50 {percentile(lats, 50) * 1e3:.1f} ms, "
              f"max {max(lats) * 1e3:.1f} ms")

    if args.smoke and mesh is not None:
        # oversize admission: bigger than every declared bucket -> sharded
        big = random_bipartite(512, 512, 4.0, seed=args.seed + 999)
        res = service.submit(big).result(timeout=300)
        direct = Matcher(service.config, service.warm_start).run(
            DeviceCSR.from_host(big).bucketed())
        ok = (res.route == "sharded"
              and res.cardinality == int(direct.cardinality))
        print(f"[serve_matching] oversize route={res.route} "
              f"|M|={res.cardinality} ({'ok' if ok else 'FAIL'})")
        failures += 0 if ok else 1

    if args.chaos:
        failures += chaos_drill(service, injector, args.size, args.seed)

    snap = service.metrics.snapshot()
    service.close()
    print(f"[serve_matching] {snap['submitted']} submitted, "
          f"{snap['dispatches']} dispatches "
          f"({snap['submitted'] / max(1, snap['dispatches']):.2f} req/dispatch), "
          f"occupancy {snap['occupancy']:.2f}, "
          f"pad-waste {snap['pad_edge_waste']:.2f}, "
          f"compile {snap['compile_hits']}h/{snap['compile_misses']}m, "
          f"flushes full/deadline/drain = {snap['flushes_full']}/"
          f"{snap['flushes_deadline']}/{snap['flushes_drain']}")
    print(f"[serve_matching] latency p50 {snap['latency_p50_ms']:.1f} ms, "
          f"p99 {snap['latency_p99_ms']:.1f} ms; queue wait p50 "
          f"{snap['queue_wait_p50_ms']:.1f} ms")
    if args.smoke:
        assert snap["dispatches"] <= snap["submitted"], \
            "batched path must not dispatch more than once per request"
        print(f"[serve_matching] smoke {'OK' if not failures else 'FAILED'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
