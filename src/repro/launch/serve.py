"""Batched serving driver: prefill a batch of prompts, then decode-step loop.

``python -m repro.launch.serve --arch <id> --smoke --batch 4 --prompt-len 32
--gen 32``  — runs real generation with the KV/SSM cache machinery (the same
serve_step the dry-run lowers at 32k/500k).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import ShapeCell, make_inputs
from repro.models import build_model
from repro.train import build_serve_step


def run(arch: str, smoke: bool, batch: int, prompt_len: int, gen: int,
        max_len: int = 0, greedy: bool = True, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(seed)
    params, _ = model.init(rng)
    max_len = max_len or (prompt_len + gen)

    shape = ShapeCell("serve", prompt_len, batch, "prefill")
    batch_in = make_inputs(cfg, shape, seed=seed)
    tokens = batch_in["tokens"]

    enc_len = batch_in["enc_frames"].shape[1] if cfg.enc_layers else 0
    cache, _ = model.init_cache(batch, max_len, enc_len=enc_len)
    if cfg.enc_layers:
        cache = model.prefill_encoder(params, cache, batch_in)

    serve_step = jax.jit(build_serve_step(model))

    # prefill by stepping (simple; a fused prefill exists via model.forward)
    out_tokens = [tokens]
    t0 = time.time()
    logits = None
    for t in range(tokens.shape[1]):
        logits, cache = serve_step(params, cache, tokens[:, t:t + 1])
    nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    gen_toks = [nxt]
    for _ in range(gen - 1):
        logits, cache = serve_step(params, cache, nxt)
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        gen_toks.append(nxt)
    dt = time.time() - t0
    gen_arr = jnp.concatenate(gen_toks, axis=1)
    total = tokens.shape[1] + gen - 1
    print(f"[serve] {arch}: batch={batch} steps={total} "
          f"({dt / total * 1000:.1f} ms/step incl. host loop)")
    return np.asarray(gen_arr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    run(args.arch, args.smoke, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
