"""Loop-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` (xla::HloCostAnalysis) visits every while body
ONCE — with ``lax.scan`` over 96 layers that under-counts FLOPs / bytes /
collective traffic by ~96x.  This module re-derives the three roofline terms
from the optimized HLO text with execution counts propagated through the
call graph:

  * ``while`` multiplies body/condition counts by the trip count XLA records
    in ``backend_config={"known_trip_count":{"n":N}}`` (statically known for
    scan); unknown trips count once and are reported in ``unknown_while``;
  * ``dot`` FLOPs = 2 * prod(output dims) * prod(lhs contracting dims),
    operand shapes resolved through a per-computation symbol table;
  * HBM-traffic bytes = operands + outputs of top-level (post-fusion)
    instructions — what a fused kernel exchanges with memory.  Fusion
    subcomputations contribute flops (their dots) but not bytes;
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ('-done' ops skipped).

Elementwise flops (reduce bodies, tanh, ...) are ignored — they are << dot
flops for every cell in this system.  Validated against analytic FLOPs in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Set, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(
    r"^\s{2,}(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_CALL_KEYS = ("body=", "condition=", "calls=", "to_apply=",
              "branch_computations=")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


class Instr:
    __slots__ = ("name", "out_type", "op", "rest", "line", "operands")

    def __init__(self, name, out_type, op, rest, line):
        self.name = name
        self.out_type = out_type
        self.op = op
        self.rest = rest
        self.line = line
        # operand names: inside the top-level parens of the op call
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        self.operands = re.findall(r"%([\w.\-]+)", rest[:end])


def parse_computations(hlo: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = []
            if mc.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    mi.group(4), line))
    return comps, entry


def _trip_count(line: str) -> Optional[int]:
    m = re.search(r'known_trip_count[\\"]*:\s*[{\\"]*n[\\"]*:[\\"]*(\d+)',
                  line)
    return int(m.group(1)) if m else None


def _called_comps(line: str) -> List[str]:
    out = []
    for key in _CALL_KEYS:
        for m in re.finditer(re.escape(key) + r"(\{[^}]*\}|%[\w.\-]+)", line):
            out += re.findall(r"%([\w.\-]+)", m.group(1))
    return out


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = parse_computations(hlo)
    if not comps:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collective_detail": {}, "unknown_while": 0}
    if entry is None:
        entry = next(iter(comps))

    # symbol tables: instruction name -> output type (params included)
    symbols: Dict[str, Dict[str, str]] = {}
    for name, instrs in comps.items():
        symbols[name] = {i.name: i.out_type for i in instrs}

    exec_count: Dict[str, float] = {n: 0.0 for n in comps}
    fusion_body: Set[str] = set()
    unknown_while = 0

    def visit(name: str, mult: float):
        nonlocal unknown_while
        if name not in comps:
            return
        exec_count[name] += mult
        for instr in comps[name]:
            called = [c for c in _called_comps(instr.line) if c in comps]
            if not called:
                continue
            child_mult = mult
            if instr.op == "while":
                trip = _trip_count(instr.line)
                if trip is None:
                    trip = 1
                    unknown_while += 1
                child_mult = mult * trip
            for c in set(called):
                if instr.op == "fusion" or "to_apply=" in instr.line:
                    fusion_body.add(c)
                visit(c, child_mult)

    visit(entry, 1.0)

    # Slice-aware read model: ops that address into a large operand read only
    # their output-sized window, NOT the whole operand (critical for scan,
    # which dynamic-slices one layer out of the stacked (L, ...) params
    # every iteration — charging the full stack would overcount ~L-fold).
    SLICE_READS = ("dynamic-slice", "slice", "gather", "reshape", "broadcast",
                   "iota", "transpose", "reverse")

    def _op_bytes(instr: Instr, table: Dict[str, str]) -> float:
        out_b = _shape_bytes(instr.out_type)
        if instr.op in SLICE_READS:
            return 2.0 * out_b                      # read window + write out
        if instr.op == "dynamic-update-slice" and len(instr.operands) >= 2:
            upd = _shape_bytes(table.get(instr.operands[1], ""))
            return 2.0 * upd                        # read update + write window
        if instr.op == "scatter" and len(instr.operands) >= 3:
            upd = _shape_bytes(table.get(instr.operands[2], ""))
            return 3.0 * upd                        # read+write region + updates
        if instr.op == "fusion":
            return out_b + _fusion_reads(instr, table)
        opnd = sum(_shape_bytes(table.get(o, "")) for o in instr.operands)
        return out_b + opnd

    def _fusion_reads(instr: Instr, table: Dict[str, str]) -> float:
        """Bytes a fused kernel reads: parameters consumed only through
        slice-like inner ops contribute the slice window, not full size."""
        called = [c for c in _called_comps(instr.line) if c in comps]
        if not called:
            return sum(_shape_bytes(table.get(o, "")) for o in instr.operands)
        body = comps[called[0]]
        # map parameter index -> instruction name
        param_names = {}
        for bi in body:
            if bi.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", bi.line)
                if m:
                    param_names[int(m.group(1))] = bi.name
        total = 0.0
        for idx, op_name in enumerate(instr.operands):
            pname = param_names.get(idx)
            full = _shape_bytes(table.get(op_name, ""))
            if pname is None:
                total += full
                continue
            consumers = [bi for bi in body if pname in bi.operands]
            if consumers and all(c.op in SLICE_READS for c in consumers):
                total += sum(_shape_bytes(c.out_type) for c in consumers)
            else:
                total += full
        return total

    flops = 0.0
    bytes_hbm = 0.0
    coll_bytes = 0.0
    coll_detail: Dict[str, float] = {c: 0.0 for c in COLLECTIVE_OPS}
    coll_top: Dict[str, float] = {}
    for name, instrs in comps.items():
        mult = exec_count.get(name, 0.0)
        if mult <= 0:
            continue
        table = symbols[name]
        for instr in instrs:
            if instr.op in ("dot", "convolution") and instr.operands:
                out_m = _SHAPE_RE.search(instr.out_type)
                lhs_t = table.get(instr.operands[0], "")
                lhs_m = _SHAPE_RE.search(lhs_t)
                if out_m and lhs_m:
                    out_elems = 1
                    for d in out_m.group(2).split(","):
                        if d:
                            out_elems *= int(d)
                    lhs_dims = [int(d) for d in lhs_m.group(2).split(",")
                                if d]
                    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                    instr.line)
                    contract = 1
                    if mcd and mcd.group(1):
                        for d in mcd.group(1).split(","):
                            contract *= lhs_dims[int(d)]
                    flops += mult * 2.0 * out_elems * contract
            if name in fusion_body:
                continue               # bytes accounted at the fusion call
            if instr.op in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "after-all", "partition-id",
                            "replica-id", "copy-start", "copy-done"):
                continue
            base = instr.op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_OPS:
                if instr.op.endswith("-done"):
                    continue
                b = sum(_shape_bytes(table.get(o, "")) for o in
                        instr.operands)
                coll_bytes += mult * b
                coll_detail[base] += mult * b
                key = f"{base} {instr.out_type.strip()} x{mult:g}"
                coll_top[key] = coll_top.get(key, 0.0) + mult * b
                continue
            bytes_hbm += mult * _op_bytes(instr, table)
    top = sorted(coll_top.items(), key=lambda kv: -kv[1])[:12]
    return {"flops": flops, "bytes": bytes_hbm,
            "collective_bytes": coll_bytes,
            "collective_detail": coll_detail,
            "collective_top": top,
            "unknown_while": unknown_while}
