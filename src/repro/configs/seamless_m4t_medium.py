"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec, multimodal (audio).

12L decoder + 12L encoder, d_model=1024, 16H (MHA kv=16), d_ff=4096,
vocab=256206.  The audio frontend is a STUB per spec: input_specs provides
precomputed frame embeddings (B, S_enc, d_model).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, act="gelu", attn="full",
    enc_layers=12, frontend="audio", frontend_len=1024,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, act="gelu", attn="full",
    enc_layers=2, frontend="audio", frontend_len=16,
    dtype="float32", remat=False,
)
