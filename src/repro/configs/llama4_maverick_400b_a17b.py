"""llama4-maverick-400b-a17b [hf:meta-llama] — MoE 128e top-1, early fusion.

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048,
128 experts top-1 + one always-on shared expert (llama4 signature).
Attention is chunked-local (8192 chunks, iRoPE-style) -> sub-quadratic,
so the long_500k cell runs.

MoE routing is where the paper's technique lands: ``router="matching"``
assigns tokens to experts with the maximum-cardinality matching router
(repro/moe/matching_router.py) instead of greedy capacity truncation.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, act="swiglu", attn="chunked", window=8192,
    n_experts=128, top_k=1, router="matching", capacity_factor=1.25,
    moe_shared_expert=True, fsdp=True,
)

SMOKE = ModelConfig(
    name="llama4-maverick-400b-a17b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, act="swiglu", attn="chunked", window=32,
    n_experts=4, top_k=1, router="matching", capacity_factor=1.25,
    moe_shared_expert=True, dtype="float32", remat=False,
)
