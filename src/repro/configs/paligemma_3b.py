"""paligemma-3b [arXiv:2407.07726; hf] — VLM: SigLIP frontend + gemma LM.

18L, d_model=2048, 8H (GQA kv=1, head_dim 256), d_ff=16384 (GeGLU),
vocab=257216.  The SigLIP vision tower is a STUB per spec: input_specs
provides 256 precomputed patch embeddings; the backbone applies PaliGemma's
prefix-LM mask (bidirectional over image+prefix, causal over suffix).
Full attention -> long_500k skipped.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, act="geglu", attn="full",
    frontend="vision", frontend_len=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512, act="geglu", attn="full",
    frontend="vision", frontend_len=8, tie_embeddings=True,
    dtype="float32", remat=False,
)
