"""dbrx-132b [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4.

40L, d_model=6144, 48H (GQA kv=8), expert d_ff=10752, vocab=100352.
Full attention -> long_500k skipped.  ``router="matching"`` applies the
paper's technique to the top-4 assignment (4 demand units per token).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, act="swiglu", attn="full",
    n_experts=16, top_k=4, router="matching", capacity_factor=1.25,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, act="swiglu", attn="full",
    n_experts=4, top_k=2, router="matching", capacity_factor=1.25,
    dtype="float32", remat=False,
)
