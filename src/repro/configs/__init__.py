"""Architecture registry: 10 assigned archs, full + smoke configs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_ARCHS = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-20b": "granite_20b",
    "zamba2-7b": "zamba2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "dbrx-132b": "dbrx_132b",
    "paligemma-3b": "paligemma_3b",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCH_NAMES: List[str] = list(_ARCHS)


def _module(name: str):
    key = name if name in _ARCHS else name.replace("_", "-")
    return importlib.import_module(f"repro.configs.{_ARCHS[key]}")


def get_config(name: str, smoke: bool = False, **overrides) -> ModelConfig:
    cfg = getattr(_module(name), "SMOKE" if smoke else "FULL")
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {n: get_config(n, smoke) for n in ARCH_NAMES}
