"""h2o-danube-1.8b [arXiv:2401.16818; hf] — dense, llama+mistral mix, SWA.

24L, d_model=2560, 32H (GQA kv=8), d_ff=6912, vocab=32000, sliding-window
attention (window 4096) -> sub-quadratic: runs the long_500k cell.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, act="swiglu", attn="swa", window=4096,
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, act="swiglu", attn="swa", window=32,
    dtype="float32", remat=False,
)
