"""granite-20b [arXiv:2405.04324; hf] — dense code model, MQA (kv=1).

52L, d_model=6144, 48H (GQA kv=1), d_ff=24576, vocab=49152.
GPT-BigCode style: non-gated GELU FFN (d_ff = 4d).  MQA: the single KV head
is replicated across the model axis (documented in launch/sharding notes).
Full attention -> long_500k skipped.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, act="gelu", attn="full",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab=512, act="gelu", attn="full",
    dtype="float32", remat=False,
)
