"""nemotron-4-340b [arXiv:2402.16819] — dense, GQA, squared-ReLU.

96L, d_model=18432, 96H (GQA kv=8), d_ff=73728, vocab=256000.
Full attention -> long_500k cell skipped (documented in DESIGN.md).
FSDP on: 340B params exceed pure-TP capacity on 256 chips.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, act="relu2", attn="full",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, act="relu2", attn="full",
    dtype="float32", remat=False,
)
