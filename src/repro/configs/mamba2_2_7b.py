"""mamba2-2.7b [arXiv:2405.21060] — attention-free SSM (SSD).

64L, d_model=2560, d_ff=0 (no FFN: mamba blocks only), vocab=50280,
ssm_state=128, expand=2, headdim=64 (80 heads).  O(1)-state decode ->
runs the long_500k cell natively.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, attn="full",
    ssm_state=128, ssm_expand=2, ssm_headdim=64, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=512, attn="full",
    ssm_state=16, ssm_expand=2, ssm_headdim=16, tie_embeddings=True,
    dtype="float32", remat=False,
)
