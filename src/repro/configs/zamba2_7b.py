"""zamba2-7b [arXiv:2411.15242] — hybrid Mamba2 + shared attention blocks.

81L mamba2 backbone, d_model=3584, shared attn block 32H (kv=32),
d_ff=14336 (shared block MLP), vocab=32000, ssm_state=64.
The shared transformer block (one set of weights) is applied every 14th
mamba block (~6 applications), approximating Zamba2's periodic shared block.
For the long_500k cell the shared block runs with a 4096 sliding window
(Zamba2's shared block is periodic; windowing it keeps the cell
sub-quadratic — noted in DESIGN.md).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, act="swiglu", attn="swa", window=4096,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, shared_every=14,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, act="swiglu", attn="swa", window=32,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, shared_every=2,
    dtype="float32", remat=False,
)
