"""The assigned input-shape set and per-(arch, shape) applicability rules.

  train_4k     seq=4096    global_batch=256   -> train_step
  prefill_32k  seq=32768   global_batch=32    -> prefill (forward)
  decode_32k   seq=32768   global_batch=128   -> serve_step (1 tok, KV 32k)
  long_500k    seq=524288  global_batch=1     -> serve_step, sub-quadratic only
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid") or cfg.attn in ("swa", "chunked")


def applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). The skip list is documented in DESIGN.md."""
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return False, "full attention is quadratic at 500k (DESIGN.md skip)"
    return True, ""


def scaled_shape(shape: ShapeCell, seq: int, batch: int) -> ShapeCell:
    """Reduced copy of a cell for smoke tests."""
    return ShapeCell(shape.name, seq, batch, shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeCell,
                dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For train/prefill this is the token batch (+ stub frontend embeddings);
    decode cells take the one-token batch — the KV cache comes from
    ``Model.init_cache`` via ``jax.eval_shape`` in the dry-run.
    """
    B, S = shape.batch, shape.seq
    emb = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, jax.ShapeDtypeStruct] = {}
        s_text = S
        if cfg.frontend == "vision":
            s_text = S - cfg.frontend_len
            batch["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), emb)
        batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), dtype)
        if cfg.enc_layers:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, max(cfg.frontend_len, S // 4), cfg.d_model), emb)
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, s_text), dtype)
        return batch
    # decode: one new token against a seq-long cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), dtype)}


def make_inputs(cfg: ModelConfig, shape: ShapeCell, seed: int = 0):
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(
                s.dtype)
    return out
