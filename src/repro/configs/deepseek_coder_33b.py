"""deepseek-coder-33b [arXiv:2401.14196; hf] — dense llama-arch.

62L, d_model=7168, 56H (GQA kv=8), d_ff=19200, vocab=32256.
Full attention -> long_500k skipped.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, act="swiglu", attn="full",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=512, act="swiglu", attn="full",
    dtype="float32", remat=False,
)
