"""Deterministic, splittable synthetic data pipeline.

Every batch is a pure function of (seed, step, host) — the property that
makes restart/straggler handling coordination-free: a replacement host
resumes mid-epoch by recomputing exactly the shards it owns, and skipping a
straggler's shard reassigns it deterministically.  A real deployment swaps
``synthetic_batch`` for a tokenized-shard reader keyed the same way.

The generator is a tiny LCG-mixed ngram sampler rather than uniform noise so
train loss actually decreases in the end-to-end example (quickstart trains a
~100M model a few hundred steps on it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Run-length token stream (copy-structure): tokens repeat in runs of
    ~2-16, 5% noise.  A small LM drops loss quickly by learning to copy,
    so the end-to-end example demonstrably trains.  Deterministic in
    (seed, step, host)."""
    per_host = cfg.global_batch // cfg.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    B, S, V = per_host, cfg.seq_len, cfg.vocab
    n_runs = S // 2 + 2
    run_tok = rng.integers(0, V, size=(B, n_runs))
    run_len = rng.integers(2, 17, size=(B, n_runs))
    seq = np.zeros((B, S + 1), dtype=np.int32)
    for b in range(B):
        reps = np.repeat(run_tok[b], run_len[b])
        seq[b] = reps[: S + 1]
    noise = rng.random((B, S + 1)) < 0.05
    seq = np.where(noise, rng.integers(0, V, size=(B, S + 1)), seq)
    seq = seq.astype(np.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def make_batches(cfg: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield step, synthetic_batch(cfg, step)
        step += 1
