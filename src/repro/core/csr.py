"""Bipartite graph container in CSR form, the paper's data layout.

The paper stores the graph as column-major CSR (``cxadj``/``cadj``): for
column ``c`` the adjacent rows are ``cadj[cxadj[c]:cxadj[c+1]]``.  The TPU
adaptation additionally materializes the *edge-parallel* view ``ecol`` (the
column endpoint of every edge) so a BFS level is one dense vector op over all
edges instead of a per-thread walk over a ragged adjacency list.

All arrays are int32 and padded to fixed sizes so the whole matcher jits once
per size bucket:

* padded edges point at a sentinel column ``nc`` and sentinel row ``nr``;
* state vectors (``cmatch``/``bfs_array``/``root``) carry one extra sentinel
  slot which is never active.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INT = np.int32

# Sentinel values shared with the matcher kernels.
UNMATCHED = -1          # vertex not matched
ENDPOINT = -2           # row discovered as an augmenting-path endpoint (paper's -2)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class BipartiteCSR:
    """Column-major CSR bipartite graph with an edge-parallel view.

    Attributes
    ----------
    nc, nr    : true number of columns / rows.
    nnz       : true number of edges.
    cxadj     : (nc+1,) CSR offsets.
    cadj      : (nnz_pad,) row endpoint per edge (sentinel ``nr`` in padding).
    ecol      : (nnz_pad,) column endpoint per edge (sentinel ``nc`` in padding).
    """

    nc: int
    nr: int
    nnz: int
    cxadj: np.ndarray
    cadj: np.ndarray
    ecol: np.ndarray

    @property
    def nnz_pad(self) -> int:
        return int(self.cadj.shape[0])

    @staticmethod
    def from_csr(cxadj: np.ndarray, cadj: np.ndarray, nc: int, nr: int,
                 pad_to: Optional[int] = None, lane: int = 128) -> "BipartiteCSR":
        cxadj = np.asarray(cxadj, dtype=INT)
        cadj = np.asarray(cadj, dtype=INT)
        nnz = int(cadj.shape[0])
        assert cxadj.shape == (nc + 1,)
        assert cxadj[-1] == nnz
        npad = pad_to if pad_to is not None else max(lane, _round_up(nnz, lane))
        assert npad >= nnz
        degrees = np.diff(cxadj)
        ecol = np.repeat(np.arange(nc, dtype=INT), degrees)
        cadj_p = np.full(npad, nr, dtype=INT)
        ecol_p = np.full(npad, nc, dtype=INT)
        cadj_p[:nnz] = cadj
        ecol_p[:nnz] = ecol
        return BipartiteCSR(nc=nc, nr=nr, nnz=nnz, cxadj=cxadj, cadj=cadj_p, ecol=ecol_p)

    @staticmethod
    def from_edges(cols: np.ndarray, rows: np.ndarray, nc: int, nr: int,
                   pad_to: Optional[int] = None) -> "BipartiteCSR":
        """Build from an unsorted edge list, deduplicating."""
        cols = np.asarray(cols, dtype=np.int64)
        rows = np.asarray(rows, dtype=np.int64)
        assert cols.shape == rows.shape
        keys = cols * np.int64(nr) + rows
        keys = np.unique(keys)
        cols = (keys // nr).astype(INT)
        rows = (keys % nr).astype(INT)
        order = np.argsort(cols, kind="stable")
        cols, rows = cols[order], rows[order]
        counts = np.bincount(cols, minlength=nc).astype(INT)
        cxadj = np.zeros(nc + 1, dtype=INT)
        np.cumsum(counts, out=cxadj[1:])
        return BipartiteCSR.from_csr(cxadj, rows, nc, nr, pad_to=pad_to)

    def to_scipy(self):
        import scipy.sparse as sp
        data = np.ones(self.nnz, dtype=np.int8)
        return sp.csr_matrix(
            (data, self.cadj[: self.nnz], self.cxadj), shape=(self.nc, self.nr)
        )

    def permuted(self, seed: int = 0) -> "BipartiteCSR":
        """Random row/column permutation — the paper's RCP instance transform."""
        rng = np.random.default_rng(seed)
        cperm = rng.permutation(self.nc).astype(INT)   # new id of old column
        rperm = rng.permutation(self.nr).astype(INT)
        cols = cperm[self.ecol[: self.nnz]]
        rows = rperm[self.cadj[: self.nnz]]
        return BipartiteCSR.from_edges(cols, rows, self.nc, self.nr,
                                       pad_to=self.nnz_pad)

    def transpose(self) -> "BipartiteCSR":
        """Row-major view (rxadj/radj) as a BipartiteCSR with roles swapped."""
        return BipartiteCSR.from_edges(self.cadj[: self.nnz], self.ecol[: self.nnz],
                                       self.nr, self.nc, pad_to=self.nnz_pad)


def validate_matching(g: BipartiteCSR, cmatch: np.ndarray, rmatch: np.ndarray) -> int:
    """Check matching validity; return its cardinality. Raises on violation."""
    cmatch = np.asarray(cmatch)[: g.nc]
    rmatch = np.asarray(rmatch)[: g.nr]
    edge_set = set(zip(g.ecol[: g.nnz].tolist(), g.cadj[: g.nnz].tolist()))
    card = 0
    for c in range(g.nc):
        r = int(cmatch[c])
        if r == UNMATCHED:
            continue
        assert 0 <= r < g.nr, f"cmatch[{c}]={r} out of range"
        assert int(rmatch[r]) == c, f"asymmetric match c={c} r={r} rmatch[r]={rmatch[r]}"
        assert (c, r) in edge_set, f"matched non-edge ({c},{r})"
        card += 1
    for r in range(g.nr):
        c = int(rmatch[r])
        if c == UNMATCHED:
            continue
        assert 0 <= c < g.nc and int(cmatch[c]) == r, f"asymmetric match r={r} c={c}"
    return card


def is_maximal(g: BipartiteCSR, cmatch: np.ndarray, rmatch: np.ndarray
               ) -> bool:
    """True iff no edge joins a free column to a free row.

    The weaker-than-maximum guarantee a phase-budget-truncated solve keeps
    (``MatcherConfig(max_phases=k, degrade_maximal=True)``): a maximal
    matching is at least half the maximum, so it is the principled
    degradation target under deadline pressure (Birn et al.).
    """
    cmatch = np.asarray(cmatch)[: g.nc]
    rmatch = np.asarray(rmatch)[: g.nr]
    cols, rows = g.ecol[: g.nnz], g.cadj[: g.nnz]
    return not bool(np.any((cmatch[cols] == UNMATCHED)
                           & (rmatch[rows] == UNMATCHED)))
