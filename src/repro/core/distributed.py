"""Distributed-memory matcher: edge-partitioned APFB over a device mesh.

The paper closes with: "an out-of-core or distributed-memory type algorithm is
amenable when the graph does not fit into the device ... We plan to
investigate the techniques to obtain good matching performance for
extreme-scale bipartite graphs."  This module is that algorithm, built on
``shard_map``:

* the edge list is 1-D sharded across the ``data`` axis of the mesh (each
  device owns ``nnz/D`` edges — the natural analog of the paper's CT strided
  edge ownership, at pod scale);
* the O(n) BFS state (``bfs``/``root``/``pred``/``cmatch``/``rmatch``) is
  replicated; each level every device computes proposals over its edge shard
  and the per-row winners merge with one ``jax.lax.pmin`` — a single
  all-reduce per BFS level, which is the minimal coordination any
  level-synchronous distributed BFS needs;
* ``ALTERNATE``/``FIXMATCHING`` act on replicated O(n) state and therefore run
  redundantly-but-identically on every device (cheaper than sharding them:
  their cost is O(n) per phase vs O(nnz/D) for expansion).

Communication per level = one pmin over an (nr+1) int32 vector; for a mesh of
D devices on ICI this is the standard ring all-reduce, 2*(D-1)/D * 4(nr+1)
bytes per link. EXPERIMENTS.md §Roofline prices this against the local
expansion cost.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                       # jax >= 0.5 exposes it top-level
    from jax import shard_map as _shard_map
except ImportError:                        # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map

from .csr import BipartiteCSR
from .matcher import (FOUND, IINF, L0, NEG, UNVISITED, MatcherConfig,
                      _alternate, _cardinality, _fix_matching)


def _expand_shard(ecol, cadj, bfs, root, pred, rmatch, level, *, wr, axis):
    """Local proposal sweep on this device's edge shard + one pmin merge."""
    nc = bfs.shape[0] - 1
    nr = pred.shape[0] - 1
    active = bfs[ecol] == level
    if wr:
        active &= bfs[root[ecol]] >= UNVISITED
    cm = rmatch[cadj]
    col_unvis = bfs[jnp.clip(cm, 0, nc)] == UNVISITED
    target = active & ((cm >= 0) & col_unvis | (cm == -1))
    prop = jnp.where(target, ecol, IINF)

    row_ix = jnp.where(prop < IINF, cadj, nr)
    winner = jnp.full(nr + 1, IINF, jnp.int32).at[row_ix].min(prop)
    winner = winner.at[nr].set(IINF)
    winner = jax.lax.pmin(winner, axis)              # merge shards: 1 collective
    upd_r = winner < IINF

    pred = jnp.where(upd_r, winner, pred)
    visit_r = upd_r & (rmatch >= 0)
    end_r = upd_r & (rmatch == -1)
    bfs = bfs.at[jnp.where(visit_r, rmatch, nc)].set(level + 1)
    if wr:
        rootvals = root[jnp.clip(winner, 0, nc)]
        root = root.at[jnp.where(visit_r, rmatch, nc)].set(
            jnp.where(visit_r, rootvals, 0))
        bfs = bfs.at[jnp.where(end_r, rootvals, nc)].min(
            jnp.where(end_r, FOUND, IINF))
    rmatch = jnp.where(end_r, jnp.int32(-2), rmatch)
    bfs = bfs.at[nc].set(NEG)
    return bfs, root, pred, rmatch, jnp.any(visit_r), jnp.any(end_r)


def _build_dist_fn(nc: int, nr: int, cfg: MatcherConfig, mesh: Mesh,
                   axis: str):
    wr = cfg.kernel == "gpubfs_wr"
    max_steps = jnp.int32(2 * (min(nc, nr) + 2))

    def shard_body(ecol, cadj, cmatch, rmatch):
        cols = jnp.arange(nc + 1, dtype=jnp.int32)

        def phase_bfs(cmatch, rmatch):
            bfs = jnp.where(cmatch >= 0, UNVISITED, L0).at[nc].set(NEG)
            root = jnp.where(cmatch >= 0, jnp.int32(nc), cols)
            pred = jnp.full(nr + 1, jnp.int32(nc), jnp.int32)

            def cond(c):
                *_, ins, aug = c
                go = ins
                if cfg.algo == "apsb":
                    go = go & ~aug
                return go

            def body(c):
                bfs, root, pred, rmatch, level, _, aug = c
                bfs, root, pred, rmatch, ins, aug_l = _expand_shard(
                    ecol, cadj, bfs, root, pred, rmatch, level, wr=wr,
                    axis=axis)
                return bfs, root, pred, rmatch, level + 1, ins, aug | aug_l

            bfs, root, pred, rmatch, _, _, aug = jax.lax.while_loop(
                cond, body, (bfs, root, pred, rmatch, L0, jnp.bool_(True),
                             jnp.bool_(False)))
            return bfs, root, pred, rmatch, aug

        def outer_body(carry):
            cmatch, rmatch, _, phases, fallbacks = carry
            cm0, rm0 = cmatch, rmatch
            card0 = _cardinality(cm0)
            bfs, root, pred, rmatch_b, aug = phase_bfs(cmatch, rmatch)

            def do_phase(_):
                mask = rmatch_b == -2
                cm1, rm1 = _alternate(
                    cm0, jnp.where(mask, jnp.int32(-2), rm0), pred, mask,
                    max_steps)
                cm1, rm1 = _fix_matching(cm1, rm1)

                def fallback(_):
                    first = jnp.argmax(mask)
                    one = jnp.zeros(nr + 1, bool).at[first].set(jnp.any(mask))
                    cm2, rm2 = _alternate(cm0, rm0, pred, one, max_steps)
                    return _fix_matching(cm2, rm2) + (jnp.int32(1),)

                return jax.lax.cond(
                    _cardinality(cm1) > card0,
                    lambda _: (cm1, rm1, jnp.int32(0)), fallback, None)

            cmatch, rmatch, fb = jax.lax.cond(
                aug, do_phase, lambda _: (cm0, rm0, jnp.int32(0)), None)
            return cmatch, rmatch, aug, phases + 1, fallbacks + fb

        def outer_cond(carry):
            *_, aug, phases, _ = carry
            return aug & (phases < nc + 2)

        carry = (cmatch, rmatch, jnp.bool_(True), jnp.int32(0), jnp.int32(0))
        cmatch, rmatch, _, phases, fallbacks = jax.lax.while_loop(
            outer_cond, outer_body, carry)
        return cmatch, rmatch, phases, fallbacks

    # disable replication checking: jax<=0.4 has no replication rule for
    # while_loop (kwarg is check_rep there, check_vma in newer releases)
    import inspect
    smap_params = inspect.signature(_shard_map).parameters
    kw = {}
    if "check_rep" in smap_params:
        kw["check_rep"] = False
    elif "check_vma" in smap_params:
        kw["check_vma"] = False
    return jax.jit(
        _shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(axis), P(axis), P(), P()),
            out_specs=(P(), P(), P(), P()),
            **kw,
        ))


def maximum_matching_distributed(
    g: BipartiteCSR,
    mesh: Mesh,
    cfg: MatcherConfig = MatcherConfig(),
    axis: str = "data",
    cmatch0: Optional[np.ndarray] = None,
    rmatch0: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Edge-partitioned distributed matcher. State replicated, edges sharded."""
    nc, nr = g.nc, g.nr
    ndev = mesh.shape[axis]
    pad = ((g.nnz_pad + ndev - 1) // ndev) * ndev
    if pad != g.nnz_pad:
        g = BipartiteCSR.from_csr(g.cxadj, g.cadj[: g.nnz], nc, nr, pad_to=pad)
    if cmatch0 is None:
        cm = np.full(nc + 1, -1, np.int32)
        rm = np.full(nr + 1, -1, np.int32)
    else:
        cm = np.concatenate([np.asarray(cmatch0, np.int32), [-1]])
        rm = np.concatenate([np.asarray(rmatch0, np.int32), [-1]])
    cm[nc], rm[nr] = -3, -3
    edge_sh = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    ecol = jax.device_put(g.ecol, edge_sh)
    cadj = jax.device_put(g.cadj, edge_sh)
    cmj = jax.device_put(cm, rep)
    rmj = jax.device_put(rm, rep)
    fn = _build_dist_fn(nc, nr, cfg, mesh, axis)
    cmo, rmo, phases, fallbacks = fn(ecol, cadj, cmj, rmj)
    cmatch = np.asarray(cmo)[:nc]
    rmatch = np.asarray(rmo)[:nr]
    return cmatch, rmatch, {
        "phases": int(phases), "fallbacks": int(fallbacks),
        "cardinality": int((cmatch >= 0).sum()), "devices": int(ndev),
        "variant": f"dist-{cfg.name}",
    }
