"""Numpy-compat wrapper over :class:`repro.matching.ShardedMatcher`.

The distributed edge-partitioned matcher (the paper's stated future work)
lives in :mod:`repro.matching.sharded` and shares the APFB/APsB solve loop,
warm-start registry, compile cache and Pallas frontier kernel with the
single-device :class:`repro.matching.Matcher` — see ``docs/architecture.md``
(design + per-level collective cost) and ``docs/paper_map.md``.  This module
keeps only the original host-centric entry point (numpy in / numpy out,
stats as a dict) for existing callers.

New code should use :class:`repro.matching.ShardedMatcher` directly::

    graph = DeviceCSR.from_host(g).shard(mesh, "data")
    state = ShardedMatcher(mesh, config=cfg, warm_start="cheap").run(graph)
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.matching import (DeviceCSR, MatcherConfig, MatchState,
                            ShardedMatcher)


def maximum_matching_distributed(
    g,
    mesh: Mesh,
    cfg: MatcherConfig = MatcherConfig(),
    axis: str = "data",
    cmatch0: Optional[np.ndarray] = None,
    rmatch0: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Edge-partitioned distributed matcher. State replicated, edges sharded.

    Thin host wrapper: uploads + shards once, runs
    :meth:`ShardedMatcher.run`, downloads once.  ``g`` is a host
    :class:`repro.core.csr.BipartiteCSR`.
    """
    graph = DeviceCSR.from_host(g).shard(mesh, axis)
    state = None
    if cmatch0 is not None:
        state = MatchState.from_host(np.asarray(cmatch0, np.int32),
                                     np.asarray(rmatch0, np.int32))
    out = ShardedMatcher(mesh, axis, cfg).run(graph, state)
    cmatch, rmatch = out.to_host()
    return cmatch, rmatch, {
        "phases": int(out.phases), "fallbacks": int(out.fallbacks),
        "cardinality": int((cmatch >= 0).sum()),
        "devices": int(mesh.shape[axis]),
        "variant": f"dist-{cfg.name}",
    }
