"""Core: the paper's maximum-cardinality bipartite matching algorithms."""
from .csr import BipartiteCSR, validate_matching, UNMATCHED, ENDPOINT
from .matcher import MatcherConfig, VARIANTS, maximum_matching
from .cheap import cheap_matching_jax
from .karp_sipser import karp_sipser_jax
from .oracles import (cheap_matching, hopcroft_karp, pfp,
                      maximum_cardinality, push_relabel)

__all__ = [
    "BipartiteCSR", "validate_matching", "UNMATCHED", "ENDPOINT",
    "MatcherConfig", "VARIANTS", "maximum_matching", "cheap_matching_jax",
    "cheap_matching", "hopcroft_karp", "pfp", "maximum_cardinality",
    "push_relabel", "karp_sipser_jax",
]
