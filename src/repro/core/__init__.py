"""Core: the paper's maximum-cardinality bipartite matching algorithms.

Host-centric compat surface (numpy in/out).  The device-resident API —
``DeviceCSR`` pytree graphs, the composable ``Matcher`` facade, batched
``match_many`` — lives in :mod:`repro.matching` and is re-exported here for
convenience.
"""
from .csr import (BipartiteCSR, is_maximal, validate_matching,
                  UNMATCHED, ENDPOINT)
from .matcher import MatcherConfig, VARIANTS, maximum_matching
from .cheap import cheap_matching_jax
from .karp_sipser import karp_sipser_jax
from .oracles import (cheap_matching, hopcroft_karp, pfp,
                      maximum_cardinality, push_relabel)
from repro.matching import (DeviceCSR, Matcher, MatchState, MatchStats,
                            match_many)

__all__ = [
    "BipartiteCSR", "is_maximal", "validate_matching", "UNMATCHED",
    "ENDPOINT",
    "MatcherConfig", "VARIANTS", "maximum_matching", "cheap_matching_jax",
    "cheap_matching", "hopcroft_karp", "pfp", "maximum_cardinality",
    "push_relabel", "karp_sipser_jax",
    "DeviceCSR", "Matcher", "MatchState", "MatchStats", "match_many",
]
