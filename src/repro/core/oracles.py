"""Sequential reference algorithms — the paper's comparison baselines.

* ``cheap_matching``  — the standard greedy initialization heuristic every
  algorithm in the paper starts from ([8]'s "cheap matching").
* ``hopcroft_karp``   — HK, O(sqrt(n)*tau): the sequential champion on the
  paper's original instances.
* ``pfp``             — Pothen–Fan with lookahead (PFP), the sequential
  champion on the permuted instances.

These run in plain numpy/python and serve as (a) correctness oracles for the
JAX matchers (maximum cardinality is unique even though the matching is not),
and (b) the sequential baselines for the speedup benchmarks, exactly like the
paper's Tables 1–2 and Figures 3–5.
"""
from __future__ import annotations

from collections import deque
from typing import Tuple

import numpy as np

from .csr import INT, UNMATCHED, BipartiteCSR

INF = np.iinfo(np.int32).max


def cheap_matching(g: BipartiteCSR) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy: match every column to its first unmatched neighbor row."""
    cmatch = np.full(g.nc, UNMATCHED, dtype=INT)
    rmatch = np.full(g.nr, UNMATCHED, dtype=INT)
    cxadj, cadj = g.cxadj, g.cadj
    for c in range(g.nc):
        for j in range(cxadj[c], cxadj[c + 1]):
            r = cadj[j]
            if rmatch[r] == UNMATCHED:
                rmatch[r] = c
                cmatch[c] = r
                break
    return cmatch, rmatch


def hopcroft_karp(g: BipartiteCSR, cmatch=None, rmatch=None) -> Tuple[np.ndarray, np.ndarray]:
    """Hopcroft–Karp with an optional warm-start matching."""
    if cmatch is None:
        cmatch = np.full(g.nc, UNMATCHED, dtype=INT)
        rmatch = np.full(g.nr, UNMATCHED, dtype=INT)
    else:
        cmatch, rmatch = cmatch.copy(), rmatch.copy()
    cxadj, cadj = g.cxadj, g.cadj
    nc = g.nc
    dist = np.zeros(nc, dtype=np.int64)

    def bfs() -> bool:
        q = deque()
        for c in range(nc):
            if cmatch[c] == UNMATCHED:
                dist[c] = 0
                q.append(c)
            else:
                dist[c] = INF
        found = INF
        while q:
            c = q.popleft()
            if dist[c] >= found:
                continue
            for j in range(cxadj[c], cxadj[c + 1]):
                r = cadj[j]
                c2 = rmatch[r]
                if c2 == UNMATCHED:
                    found = min(found, dist[c] + 1)
                elif dist[c2] == INF:
                    dist[c2] = dist[c] + 1
                    q.append(c2)
        return found != INF

    def dfs(c: int) -> bool:
        for j in range(cxadj[c], cxadj[c + 1]):
            r = cadj[j]
            c2 = rmatch[r]
            if c2 == UNMATCHED or (dist[c2] == dist[c] + 1 and dfs(c2)):
                cmatch[c] = r
                rmatch[r] = c
                return True
        dist[c] = INF
        return False

    import sys
    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old, g.nc + g.nr + 100))
    try:
        while bfs():
            for c in range(nc):
                if cmatch[c] == UNMATCHED:
                    dfs(c)
    finally:
        sys.setrecursionlimit(old)
    return cmatch, rmatch


def pfp(g: BipartiteCSR, cmatch=None, rmatch=None) -> Tuple[np.ndarray, np.ndarray]:
    """Pothen–Fan: phase-wise disjoint DFS augmentation with lookahead.

    Iterative DFS (explicit stack) so deep paths do not overflow recursion.
    """
    if cmatch is None:
        cmatch = np.full(g.nc, UNMATCHED, dtype=INT)
        rmatch = np.full(g.nr, UNMATCHED, dtype=INT)
    else:
        cmatch, rmatch = cmatch.copy(), rmatch.copy()
    cxadj, cadj = g.cxadj, g.cadj
    nc = g.nc
    lookahead = g.cxadj[:-1].astype(np.int64).copy()   # per-column lookahead cursor
    visited_row = np.full(g.nr, -1, dtype=np.int64)    # phase stamp

    phase = 0
    while True:
        phase += 1
        augmented = 0
        for c0 in range(nc):
            if cmatch[c0] != UNMATCHED:
                continue
            # Iterative DFS from unmatched column c0.
            path_c = [c0]
            ptr = [cxadj[c0]]
            end_row = -1
            while path_c:
                c = path_c[-1]
                # 1) lookahead: any unmatched row directly adjacent?
                la = lookahead[c]
                hit = -1
                while la < cxadj[c + 1]:
                    r = cadj[la]
                    la += 1
                    if rmatch[r] == UNMATCHED:
                        hit = r
                        break
                lookahead[c] = la
                if hit >= 0:
                    end_row = hit
                    visited_row[hit] = phase
                    break
                # 2) advance DFS over matched rows not yet visited this phase
                j = ptr[-1]
                advanced = False
                while j < cxadj[c + 1]:
                    r = cadj[j]
                    j += 1
                    if visited_row[r] != phase and rmatch[r] != UNMATCHED:
                        visited_row[r] = phase
                        ptr[-1] = j
                        path_c.append(rmatch[r])
                        ptr.append(cxadj[rmatch[r]])
                        advanced = True
                        break
                if advanced:
                    continue
                path_c.pop()
                ptr.pop()
            if end_row >= 0:
                # augment along path_c; path rows recovered from matches
                r = end_row
                for c in reversed(path_c):
                    nxt = cmatch[c]
                    cmatch[c] = r
                    rmatch[r] = c
                    r = nxt
                augmented += 1
        if augmented == 0:
            break
    return cmatch, rmatch


def maximum_cardinality(g: BipartiteCSR) -> int:
    """Oracle cardinality via scipy's csgraph matching (independent code path)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    m = sp.csr_matrix(
        (np.ones(g.nnz, dtype=np.int8), g.cadj[: g.nnz], g.cxadj),
        shape=(g.nc, g.nr),
    )
    match = maximum_bipartite_matching(m, perm_type="column")
    return int((match >= 0).sum())


def push_relabel(g: BipartiteCSR, cmatch=None, rmatch=None,
                 max_ops: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Push-relabel matching (the paper's second algorithm class [12, 16]).

    Kaya et al. (2012) style double-push: an unmatched column either grabs a
    free row or steals the row whose owner has the lowest height, relabels,
    and re-queues the evicted column. O(n*tau); used as a third sequential
    baseline in the benchmarks.
    """
    from collections import deque

    if cmatch is None:
        cmatch = np.full(g.nc, UNMATCHED, dtype=INT)
        rmatch = np.full(g.nr, UNMATCHED, dtype=INT)
    else:
        cmatch, rmatch = cmatch.copy(), rmatch.copy()
    cxadj, cadj = g.cxadj, g.cadj
    psi = np.zeros(g.nc, dtype=np.int64)             # heights
    limit = max_ops or 4 * (g.nc + 1) * max(1, g.nnz)
    q = deque(c for c in range(g.nc) if cmatch[c] == UNMATCHED)
    ops = 0
    while q and ops < limit:
        c = q.popleft()
        if cxadj[c] == cxadj[c + 1]:
            continue                                  # isolated column
        if psi[c] >= g.nc:
            continue      # height >= n: no augmenting path exists (standard
            #               push-relabel termination bound for matching)
        best_r, best_psi = -1, None
        done = False
        for j in range(cxadj[c], cxadj[c + 1]):
            ops += 1
            r = cadj[j]
            if rmatch[r] == UNMATCHED:
                cmatch[c] = r
                rmatch[r] = c
                done = True
                break
            h = psi[rmatch[r]]
            if best_psi is None or h < best_psi:
                best_psi, best_r = h, r
        if done:
            continue
        # double push: steal best_r, relabel, re-queue the evicted column
        c2 = rmatch[best_r]
        rmatch[best_r] = c
        cmatch[c] = best_r
        cmatch[c2] = UNMATCHED
        psi[c] = best_psi + 1
        psi[c2] += 1
        q.append(c2)
    return cmatch, rmatch
