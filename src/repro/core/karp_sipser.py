"""Numpy-compat wrapper for the Karp–Sipser warm start (beyond-paper).

The pure peel-then-greedy initializer lives in
:mod:`repro.matching.warmstart` (registry name ``"karp_sipser"``); see that
module for the algorithm notes.  Quality: on the benchmark suite KS leaves
~2-4x fewer unmatched vertices than cheap matching
(benchmarks/table_init.py), which cuts matcher phases.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.matching.warmstart import karp_sipser_init                # noqa: F401

from .cheap import _run_init
from .csr import BipartiteCSR


def karp_sipser_jax(g: BipartiteCSR) -> Tuple[np.ndarray, np.ndarray]:
    """KS degree-1 peeling rounds, then parallel greedy on the residual."""
    return _run_init(g, "karp_sipser")
