"""Karp–Sipser initialization, data-parallel (beyond the paper's cheap init).

The matching literature's stronger initializer ([8] §4, Magun '98): while
the residual graph has a degree-1 vertex, matching its only edge is optimal;
when none remains, fall back to greedy.  Sequential KS peels one vertex at a
time; the TPU adaptation peels *all* current degree-1 vertices per round
(speculatively — two degree-1 columns may claim one row) with the same
min-scatter conflict resolution + feasibility repair as the main matcher,
then finishes with the parallel cheap matching on the residual.

Quality: on the benchmark suite KS leaves ~2-4x fewer unmatched vertices
than cheap matching (benchmarks/table_init.py), which cuts matcher phases.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import BipartiteCSR

IINF = jnp.int32(2**30)


def _build(nc: int, nr: int):
    def degree_round(carry):
        ecol, cadj, cmatch, rmatch, _ = carry
        alive = (cmatch[ecol] == -1) & (rmatch[cadj] == -1)
        one = jnp.int32(1)
        cdeg = jnp.zeros(nc + 1, jnp.int32).at[
            jnp.where(alive, ecol, nc)].add(one)
        rdeg = jnp.zeros(nr + 1, jnp.int32).at[
            jnp.where(alive, cadj, nr)].add(one)
        # forced edges: endpoint with residual degree 1
        forced = alive & ((cdeg[ecol] == 1) | (rdeg[cadj] == 1))

        # speculative commit of all forced edges, min-scatter per column/row
        prop_r = jnp.full(nc + 1, IINF).at[
            jnp.where(forced, ecol, nc)].min(jnp.where(forced, cadj, IINF))
        col_has = prop_r < IINF
        # rows accept lowest proposing column among columns that picked them
        cols = jnp.arange(nc + 1, dtype=jnp.int32)
        prop_c = jnp.full(nr + 1, IINF).at[
            jnp.where(col_has, prop_r, nr)].min(jnp.where(col_has, cols,
                                                          IINF))
        rows = jnp.arange(nr + 1, dtype=jnp.int32)
        won_r = prop_c < IINF                       # row r matched to prop_c[r]
        rmatch = jnp.where(won_r & (rmatch == -1), prop_c, rmatch)
        # commit winning columns (repair: only pairs where row accepted col)
        won_pair = won_r & (rmatch == prop_c)
        cmatch = cmatch.at[jnp.where(won_pair, jnp.clip(prop_c, 0, nc), nc)
                           ].max(jnp.where(won_pair, rows, jnp.int32(-1)))
        cmatch = cmatch.at[nc].set(jnp.int32(-3))
        rmatch = rmatch.at[nr].set(jnp.int32(-3))
        progress = jnp.any(forced)
        return ecol, cadj, cmatch, rmatch, progress

    def cond(carry):
        return carry[-1]

    def fn(ecol, cadj, cmatch, rmatch):
        carry = (ecol, cadj, cmatch, rmatch, jnp.bool_(True))
        carry = jax.lax.while_loop(cond, degree_round, carry)
        return carry[2], carry[3]

    return fn


@functools.lru_cache(maxsize=256)
def _jitted(nc: int, nr: int):
    return jax.jit(_build(nc, nr))


def karp_sipser_jax(g: BipartiteCSR) -> Tuple[np.ndarray, np.ndarray]:
    """KS degree-1 peeling rounds, then parallel greedy on the residual."""
    from .cheap import _jitted as _cheap_jitted

    nc, nr = g.nc, g.nr
    cm = jnp.full(nc + 1, jnp.int32(-1)).at[nc].set(jnp.int32(-3))
    rm = jnp.full(nr + 1, jnp.int32(-1)).at[nr].set(jnp.int32(-3))
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    cmj, rmj = _jitted(nc, nr)(ecol, cadj, cm, rm)
    cmj, rmj = _cheap_jitted(nc, nr)(ecol, cadj, cmj, rmj)
    # repair any asymmetric remnants of the speculative commits
    rows = jnp.arange(nr + 1, dtype=jnp.int32)
    cols = jnp.arange(nc + 1, dtype=jnp.int32)
    ok_r = (rmj >= 0) & (cmj[jnp.clip(rmj, 0, nc)] == rows)
    rmj = jnp.where((rmj >= 0) & ~ok_r, jnp.int32(-1), rmj)
    ok_c = (cmj >= 0) & (rmj[jnp.clip(cmj, 0, nr)] == cols)
    cmj = jnp.where((cmj >= 0) & ~ok_c, jnp.int32(-1), cmj)
    return np.asarray(cmj)[:nc], np.asarray(rmj)[:nr]
