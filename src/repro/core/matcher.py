"""Numpy-compat wrapper over the device-resident ``repro.matching`` API.

The solver itself (the paper's APFB/APsB drivers, GPUBFS/GPUBFS-WR expansion,
ALTERNATE + FIXMATCHING) lives in :mod:`repro.matching.solve` as pure
shape-polymorphic JAX; this module keeps the original host-centric entry
point :func:`maximum_matching` (numpy in / numpy out, stats as a dict) and
re-exports the kernel internals for the instrumented benchmarks, the
distributed matcher and the Pallas kernel tests.

New code should use :class:`repro.matching.Matcher` directly — it keeps
graphs and matcher state on device and composes under ``jit``/``vmap``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.matching.config import MatcherConfig, VARIANTS           # noqa: F401
from repro.matching.solve import (FOUND, IINF, L0, NEG, UNVISITED,   # noqa: F401
                                  _alternate, _cardinality,
                                  _expand_level, _fix_matching,
                                  default_block_edges, make_solver,
                                  scatter_min)
from repro.matching.api import Matcher
from repro.matching.device_csr import DeviceCSR
from repro.matching.state import MatchState

from .csr import BipartiteCSR


def maximum_matching(
    g: BipartiteCSR,
    cfg: MatcherConfig = MatcherConfig(),
    cmatch0: Optional[np.ndarray] = None,
    rmatch0: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Run one of the paper's matcher variants to a maximum matching.

    Returns (cmatch, rmatch, stats) as numpy arrays of true (unpadded) size.
    Thin host wrapper: uploads once, runs :meth:`Matcher.run`, downloads once.
    """
    graph = DeviceCSR.from_host(g)
    if cfg.dirop:
        graph = graph.with_csc()    # the pull sweep gathers the CSC mirror
    state = None
    if cmatch0 is not None:
        state = MatchState.from_host(np.asarray(cmatch0, np.int32),
                                     np.asarray(rmatch0, np.int32))
    out = Matcher(cfg).run(graph, state)
    cmatch, rmatch = out.to_host()
    stats = {"phases": int(out.phases), "fallbacks": int(out.fallbacks),
             "cardinality": int((cmatch >= 0).sum()),
             "certified": bool(out.certified), "variant": cfg.name}
    return cmatch, rmatch, stats
