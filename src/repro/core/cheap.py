"""Numpy-compat wrapper for the parallel cheap-matching warm start.

The pure initializer lives in :mod:`repro.matching.warmstart` (registry name
``"cheap"``) so :class:`repro.matching.Matcher` can fuse it with the solver in
one compiled program.  This wrapper keeps the original numpy in/out entry
point for the sequential baselines and benchmarks.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.matching.api import Matcher
from repro.matching.device_csr import DeviceCSR
from repro.matching.warmstart import cheap_init                      # noqa: F401

from .csr import BipartiteCSR


def _run_init(g: BipartiteCSR, name: str) -> Tuple[np.ndarray, np.ndarray]:
    state = Matcher(warm_start=name).init(DeviceCSR.from_host(g))
    return state.to_host()


def cheap_matching_jax(g: BipartiteCSR) -> Tuple[np.ndarray, np.ndarray]:
    return _run_init(g, "cheap")
