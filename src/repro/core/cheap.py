"""Parallel cheap-matching initialization (the paper's common warm start).

The paper initializes every algorithm with the sequential "cheap matching"
greedy heuristic [8].  The TPU adaptation is a speculative round-based greedy
(propose -> resolve -> commit), the same speculate-then-repair pattern as the
main matcher: each round, every unmatched column proposes its lowest-index
unmatched neighbor row; each proposed row accepts its lowest proposing
column; accepted pairs commit.  Rounds repeat until no proposal survives,
which yields a maximal greedy matching (quality comparable to sequential
cheap matching; benchmarked in bench_matching).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import BipartiteCSR

IINF = jnp.int32(2**30)


def _build(nc: int, nr: int):
    def round_fn(carry):
        ecol, cadj, cmatch, rmatch, _ = carry
        col_free = cmatch[ecol] == -1
        row_free = rmatch[cadj] == -1
        cand = jnp.where(col_free & row_free, cadj, IINF)
        best_r = jnp.full(nc + 1, IINF, jnp.int32).at[ecol].min(cand)
        best_r = best_r.at[nc].set(IINF)
        cols = jnp.arange(nc + 1, dtype=jnp.int32)
        propose = best_r < IINF
        best_c = jnp.full(nr + 1, IINF, jnp.int32).at[
            jnp.where(propose, best_r, nr)].min(jnp.where(propose, cols, IINF))
        best_c = best_c.at[nr].set(IINF)
        won = best_c < IINF                                  # per-row accept
        rows = jnp.arange(nr + 1, dtype=jnp.int32)
        rmatch = jnp.where(won, best_c, rmatch)
        cmatch = cmatch.at[jnp.where(won, best_c, nc)].set(
            jnp.where(won, rows, cmatch[nc]))
        cmatch = cmatch.at[nc].set(jnp.int32(-3))
        return ecol, cadj, cmatch, rmatch, jnp.any(won)

    def cond(carry):
        return carry[-1]

    def fn(ecol, cadj, cmatch, rmatch):
        carry = (ecol, cadj, cmatch, rmatch, jnp.bool_(True))
        carry = jax.lax.while_loop(cond, round_fn, carry)
        return carry[2], carry[3]

    return fn


@functools.lru_cache(maxsize=256)
def _jitted(nc: int, nr: int):
    return jax.jit(_build(nc, nr))


def cheap_matching_jax(g: BipartiteCSR) -> Tuple[np.ndarray, np.ndarray]:
    nc, nr = g.nc, g.nr
    cm = jnp.full(nc + 1, jnp.int32(-1)).at[nc].set(jnp.int32(-3))
    rm = jnp.full(nr + 1, jnp.int32(-1)).at[nr].set(jnp.int32(-3))
    cmj, rmj = _jitted(nc, nr)(jnp.asarray(g.ecol), jnp.asarray(g.cadj), cm, rm)
    return np.asarray(cmj)[:nc], np.asarray(rmj)[:nr]
