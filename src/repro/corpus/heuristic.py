"""Deterministic replay of the direction-optimizing push/pull heuristic.

The dirop engine decides push vs pull per BFS level from two O(n) degree
sums (``fe`` = frontier columns' outgoing edges, ``pe`` = unreached rows'
incoming edges — see :func:`repro.matching.solve._expand_level_dirop`).
Wall-clock benchmarks of that decision flake on shared CI runners, so the
per-family gate instead replays the *exact* level states the solver sweeps
(every sweep path is bit-identical, so the dense jnp replay sees the same
``bfs``/``rmatch`` trajectory dirop would) and prices the decisions with a
fixed work model:

* a push level sweeps every padded edge tile and merges:
  ``cost = ntiles * LANE`` (= the padded edge count);
* a pull level pays ``PULL_TILE_OVERHEAD`` lanes per CSC tile (the stream +
  skip decision) and full ``LANE`` cost only for tiles that actually
  contain an unreached row's edge — the tile-skip win of the streaming
  ``frontier_expand_pull`` kernel, which is large when the remaining rows
  are clustered (late levels, road/comb-like instances) and small when RCP
  permutation scatters them.

``modelled_rel`` = dirop cost / push-only cost is then a pure function of
(instance, warm start, alpha, beta): deterministic, portable across
machines, and sensitive to exactly the regression class the gate is for —
an always-pull ``alpha``/``beta`` prices early levels (every tile occupied)
at ``~(1 + PULL_TILE_OVERHEAD/LANE)`` of a push sweep and the per-family
``rel`` rows move far past any gate tolerance.  The committed alpha/beta
sweep in ``BENCH_PR7.json`` (``corpus.alpha_sweep``) is what the
:class:`~repro.matching.MatcherConfig` dirop defaults cite.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import BipartiteCSR
from repro.core.matcher import maximum_matching
from repro.matching import MatcherConfig, MatchState
from repro.matching.solve import L0, UNVISITED, _expand_level, level0_state
from repro.matching.warmstart import get_warm_start

# the model's tile geometry: LANE matches the kernels' 128-lane tiles;
# PULL_TILE_OVERHEAD is the lanes-equivalent a pull sweep pays per tile
# just to stream it and decide to skip.  Model constants, not measurements:
# they only need to make always-pull measurably worse than push on early
# levels (every tile occupied) and tile-skipping pulls measurably better.
LANE = 128
PULL_TILE_OVERHEAD = 16
# kept as the documented ratio for reporting; the cost formulas use the
# tile constants directly
PULL_STREAM_FRACTION = PULL_TILE_OVERHEAD / LANE

# the replay's dense expansion step: the solver's own _expand_level with the
# default-variant statics (APFB / gpubfs_wr / jnp sweep).  block_edges is a
# Pallas-only knob, inert on the jnp path.
_STEP = jax.jit(functools.partial(_expand_level, wr=True, wr_exact=False,
                                  use_pallas=False, block_edges=128))

_BASE = MatcherConfig(algo="apfb", kernel="gpubfs_wr")


@dataclasses.dataclass(frozen=True)
class HeuristicTrace:
    """Per-phase, per-level ``(fe, pe, touched_tiles)`` for one instance +
    warm start.  ``fe``/``pe`` are the solver's exact decision inputs;
    ``touched_tiles`` counts CSC edge tiles containing at least one
    unreached row's edge (the pull sweep's non-skippable tiles)."""
    phases: Tuple[Tuple[Tuple[float, float, int], ...], ...]
    nnz_pad: int

    @property
    def ntiles(self) -> int:
        return -(-self.nnz_pad // LANE)

    @property
    def levels(self) -> int:
        return sum(len(p) for p in self.phases)


def _replay_phase(ecol, cadj, cdeg, rdeg, erow_host, tile_of_slot, cm, rm
                  ) -> List[Tuple[float, float, int]]:
    """Eagerly run one phase's BFS levels, recording (fe, pe) before each
    expansion — the exact sums ``_expand_level_dirop`` computes — plus the
    pull sweep's touched-tile count for the work model."""
    nc = cm.shape[0] - 1
    state = MatchState.from_host(cm, rm)
    bfs, root = level0_state(state.cmatch)
    pred = jnp.full(rm.shape[0] + 1, jnp.int32(nc), jnp.int32)
    rmatch = state.rmatch
    out: List[Tuple[float, float, int]] = []
    level = L0
    while True:
        bfs_h = np.asarray(bfs)
        isf = bfs_h[:-1] == level
        isf &= bfs_h[np.clip(np.asarray(root)[:-1], 0, nc)] >= UNVISITED
        fe = float(np.sum(np.where(isf, cdeg, 0)))
        rm_h = np.asarray(rmatch)[:-1]
        unreached = (rm_h == -1) | ((rm_h >= 0)
                                    & (bfs_h[np.clip(rm_h, 0, nc)]
                                       == UNVISITED))
        pe = float(np.sum(np.where(unreached, rdeg, 0)))
        touched = int(np.unique(tile_of_slot[unreached[erow_host]]).size)
        out.append((fe, pe, touched))
        bfs, root, pred, rmatch, ins, _ = _STEP(ecol, cadj, bfs, root, pred,
                                                rmatch, jnp.int32(level))
        if not bool(ins):
            return out
        level += 1


def trace_instance(g: BipartiteCSR, warm_start: str = "cheap",
                   max_phases: int = 128) -> HeuristicTrace:
    """Replay every BFS phase of the default solver on ``g`` and collect the
    per-level (fe, pe) direction inputs.

    Phase starting states advance through the *real* solver
    (``max_phases=1`` per step), so the trace is exactly the level sequence
    any sweep path executes on this instance — the decisions priced by
    :func:`modelled_rel` are the ones dirop would take online.
    """
    ecol = jnp.asarray(g.ecol)
    cadj = jnp.asarray(g.cadj)
    cdeg = np.diff(g.cxadj).astype(np.int64)
    rdeg = np.bincount(g.cadj[: g.nnz], minlength=g.nr)[: g.nr]
    # CSC slot -> (row, tile): which pull tiles an unreached-row set occupies
    order = np.argsort(g.cadj[: g.nnz], kind="stable")
    erow_host = g.cadj[: g.nnz][order]
    tile_of_slot = np.arange(g.nnz, dtype=np.int64) // LANE
    fresh = MatchState.fresh(g.nc, g.nr)
    cm, rm = (np.asarray(a, np.int32)[:-1]
              for a in get_warm_start(warm_start)(
                  ecol, cadj, fresh.cmatch, fresh.rmatch))
    step_cfg = dataclasses.replace(_BASE, max_phases=1)
    phases = []
    card = int(np.sum(cm >= 0))
    for _ in range(max_phases):
        phases.append(tuple(_replay_phase(ecol, cadj, cdeg, rdeg, erow_host,
                                          tile_of_slot, cm, rm)))
        cm, rm, _ = maximum_matching(g, step_cfg, cm, rm)
        gained = int(np.sum(cm >= 0)) - card
        card += gained
        if gained <= 0:
            break
    return HeuristicTrace(phases=tuple(phases), nnz_pad=g.nnz_pad)


def modelled_rel(trace: HeuristicTrace, alpha: float, beta: float
                 ) -> Tuple[float, int]:
    """(dirop cost / push-only cost, pull-level count) under the work model.

    Applies the solver's exact decision rule — ``pull = fe*alpha > pe`` or,
    while already pulling, ``fe*beta > pe`` (``dir_prev`` resets each phase,
    as in the solver's phase loop) — to the traced (fe, pe) sequence, then
    prices each level with the tile work model (module docstring).
    """
    ntiles = trace.ntiles
    push_level = float(ntiles * LANE)
    push_total = dirop_total = 0.0
    pulls = 0
    for phase in trace.phases:
        prev = False
        for fe, pe, touched in phase:
            pull = (fe * alpha > pe) or (prev and fe * beta > pe)
            dirop_total += ((ntiles * PULL_TILE_OVERHEAD + touched * LANE)
                            if pull else push_level)
            push_total += push_level
            pulls += int(pull)
            prev = pull
    return dirop_total / max(push_total, 1.0), pulls


def sweep_grid() -> Sequence[Tuple[float, float]]:
    """The committed (alpha, beta) sweep: never-pull and always-pull anchors
    around a log-spaced band (beta = 4*alpha keeps the hysteresis shape)."""
    return ((1e-6, 1e-6), (1.0, 4.0), (2.0, 8.0), (4.0, 16.0), (8.0, 32.0),
            (16.0, 64.0), (1e6, 1e6))
