"""Differential fuzz harness: every solve path × warm start × corpus family.

For each corpus instance (the unified :func:`repro.graphs.instance_sets`
families plus the committed ``.mtx`` fixture, original + RCP-permuted) and
each registered solve path (:data:`repro.matching.SOLVE_PATHS`) × warm-start
config, the harness asserts

* the :func:`repro.core.csr.validate_matching` invariants (symmetry, range,
  edge membership), and
* cardinality equals the host Hopcroft-Karp oracle,

with deterministic seeds throughout.  On a mismatch it ddmin-minimizes the
instance's edge list against the failing (path, warm start) cell and dumps a
JSON artifact (``repro-corpus-failure/1``) with the minimized edges, the
config, and both cardinalities — a ready-to-replay reproducer.

Compile budget: all instances are padded into one shared size bucket, so
the device compiles one program per (path, warm start) cell for the whole
corpus instead of one per instance.

CLI::

    python -m repro.corpus.verify --scale mini --artifact-dir artifacts

exits non-zero on any failing cell.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.csr import BipartiteCSR, is_maximal, validate_matching
from repro.core.oracles import hopcroft_karp
from repro.graphs import instance_sets, mtx_fixture
from repro.matching import SOLVE_PATHS, MatcherConfig
from repro.matching.device_csr import bucket_nnz

ARTIFACT_SCHEMA = "repro-corpus-failure/1"
DEFAULT_WARM_STARTS = ("none", "cheap")


def corpus_instances(scale: str = "mini", rcp: bool = True,
                     rcp_seed: int = 13,
                     families: Optional[Sequence[str]] = None
                     ) -> Dict[str, BipartiteCSR]:
    """The corpus: unified generator families + the committed mtx fixture,
    each optionally with its RCP-permuted twin."""
    insts = instance_sets(scale, rcp=False)
    insts["mtx"] = mtx_fixture()
    if families is not None:
        insts = {k: insts[k] for k in families}
    if rcp:
        insts.update({f"{k}_rcp": g.permuted(rcp_seed)
                      for k, g in tuple(insts.items())})
    return insts


def oracle_cardinality(g: BipartiteCSR) -> int:
    cm, rm = hopcroft_karp(g)
    return int(validate_matching(g, cm, rm))


def shared_bucket(insts) -> Tuple[int, int, int]:
    """One (nc, nr, nnz_cap) bucket every corpus instance pads into."""
    nc = max(g.nc for g in insts)
    nr = max(g.nr for g in insts)
    cap = bucket_nnz(max(g.nnz_pad for g in insts))
    return nc, nr, cap


@dataclasses.dataclass
class CellResult:
    instance: str
    path: str
    warm_start: str
    expected: int
    cardinality: int = -1
    ok: bool = False
    error: str = ""
    artifact: str = ""


@dataclasses.dataclass
class FuzzReport:
    results: List[CellResult]

    @property
    def failures(self) -> List[CellResult]:
        return [r for r in self.results if not r.ok]

    def summary(self) -> str:
        n = len(self.results)
        bad = self.failures
        lines = [f"corpus fuzz: {n - len(bad)}/{n} cells ok"]
        lines += [f"  FAIL {r.instance} path={r.path} ws={r.warm_start} "
                  f"card={r.cardinality} expected={r.expected} "
                  f"{r.error} artifact={r.artifact or '-'}" for r in bad]
        return "\n".join(lines)


def minimize_failing_edges(cols, rows, nc: int, nr: int,
                           fails: Callable[[np.ndarray], bool],
                           max_checks: int = 64) -> np.ndarray:
    """ddmin-style edge-list minimization, budgeted by solver re-checks.

    Repeatedly drops contiguous chunks of the (col, row) edge list while
    ``fails`` keeps reproducing; returns the reduced ``(k, 2)`` edge array.
    The budget bounds total solver invocations, so a pathological failure
    cannot hang the harness.
    """
    edges = np.stack([np.asarray(cols, np.int64)[: len(rows)],
                      np.asarray(rows, np.int64)], axis=1)
    n, checks = 2, 0
    while edges.shape[0] >= 2 and checks < max_checks:
        chunk = -(-edges.shape[0] // n)
        reduced = False
        for i in range(0, edges.shape[0], chunk):
            cand = np.concatenate([edges[:i], edges[i + chunk:]])
            if cand.shape[0] == 0:
                continue
            checks += 1
            if fails(cand):
                edges, n, reduced = cand, max(2, n - 1), True
                break
            if checks >= max_checks:
                break
        if not reduced:
            if n >= edges.shape[0]:
                break
            n = min(edges.shape[0], n * 2)
    return edges


def _run_cell(path, g: BipartiteCSR, base: MatcherConfig, ws: str,
              pad, oracle: str = "maximum") -> Tuple[int, str]:
    """(cardinality, error) for one solve; -1 cardinality on exception.

    ``oracle`` picks the contract checked beyond validity:
    ``"maximum"`` (default) leaves the cardinality comparison to the
    caller; ``"maximal"`` — the degraded-mode contract of a
    ``max_phases``-budgeted solve — additionally asserts no free column
    shares an edge with a free row.
    """
    try:
        cm, rm = path.run_host(g, base=base, warm_start=ws, pad=pad)
        card = int(validate_matching(g, cm, rm))
        if oracle == "maximal" and not is_maximal(g, cm, rm):
            return card, "not maximal: a free column-free row edge remains"
        return card, ""
    except Exception as e:  # noqa: BLE001 — fuzzing: any failure is a finding
        return -1, f"{type(e).__name__}: {e}"


def _dump_artifact(artifact_dir: str, res: CellResult, g: BipartiteCSR,
                   cfg: MatcherConfig, edges: np.ndarray, seed: int,
                   minimized: bool) -> str:
    os.makedirs(artifact_dir, exist_ok=True)
    out = os.path.join(
        artifact_dir,
        f"corpus_failure_{res.instance}_{res.path}_{res.warm_start}.json")
    with open(out, "w") as f:
        json.dump({
            "schema": ARTIFACT_SCHEMA,
            "instance": res.instance, "path": res.path,
            "warm_start": res.warm_start,
            "config": dataclasses.asdict(cfg),
            "nc": g.nc, "nr": g.nr, "seed": seed,
            "expected": res.expected, "got": res.cardinality,
            "error": res.error, "minimized": minimized,
            "edges": edges.tolist(),
        }, f, indent=2, sort_keys=True)
    return out


def verify_corpus(scale: str = "mini",
                  paths: Optional[Sequence[str]] = None,
                  warm_starts: Sequence[str] = DEFAULT_WARM_STARTS,
                  rcp: bool = True, seed: int = 13,
                  families: Optional[Sequence[str]] = None,
                  base: MatcherConfig = MatcherConfig(),
                  artifact_dir: str = ".",
                  budget: Optional[int] = None,
                  minimize: bool = True,
                  minimize_budget: int = 64,
                  oracle: str = "maximum") -> FuzzReport:
    """Run the differential matrix; never raises — read ``.failures``.

    ``budget`` caps the number of (instance, path, warm start) cells; the
    enumeration rotates the path order per instance so a small budget still
    touches every solve path early.

    ``oracle="maximum"`` (default) demands Hopcroft-Karp cardinality;
    ``oracle="maximal"`` is the degraded-mode gate for phase-budgeted
    configs (``base.max_phases`` small): the matching must be valid,
    maximal, and no larger than the true maximum.
    """
    if oracle not in ("maximum", "maximal"):
        raise ValueError(f"unknown oracle {oracle!r}")
    insts = corpus_instances(scale, rcp=rcp, rcp_seed=seed,
                             families=families)
    names = list(paths) if paths is not None else list(SOLVE_PATHS)
    pad = shared_bucket(insts.values())
    expected = {k: oracle_cardinality(g) for k, g in insts.items()}

    cells = []
    for i, iname in enumerate(insts):
        for j in range(len(names)):
            pn = names[(i + j) % len(names)]
            cells.extend((iname, pn, ws) for ws in warm_starts)
    if budget is not None:
        cells = cells[:budget]

    results = []
    for iname, pn, ws in cells:
        g = insts[iname]
        path = SOLVE_PATHS[pn]
        card, err = _run_cell(path, g, base, ws, pad, oracle=oracle)
        ok = not err and (card <= expected[iname] if oracle == "maximal"
                          else card == expected[iname])
        res = CellResult(instance=iname, path=pn, warm_start=ws,
                         expected=expected[iname], cardinality=card,
                         ok=ok, error=err)
        if not res.ok:
            edges = np.stack([g.ecol[: g.nnz], g.cadj[: g.nnz]], axis=1)
            minimized = False
            if minimize:
                # fixed-size bucket per candidate: one compiled program
                # serves every minimization re-check
                mpad = (g.nc, g.nr, bucket_nnz(g.nnz_pad))

                def fails(cand):
                    gg = BipartiteCSR.from_edges(cand[:, 0], cand[:, 1],
                                                 g.nc, g.nr)
                    c, e = _run_cell(path, gg, base, ws, mpad, oracle=oracle)
                    if oracle == "maximal":
                        return bool(e) or c > oracle_cardinality(gg)
                    return bool(e) or c != oracle_cardinality(gg)

                edges = minimize_failing_edges(
                    g.ecol[: g.nnz], g.cadj[: g.nnz], g.nc, g.nr, fails,
                    max_checks=minimize_budget)
                minimized = True
            res.artifact = _dump_artifact(
                artifact_dir, res, g, path.configure(base), edges, seed,
                minimized)
        results.append(res)
    return FuzzReport(results=results)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="differential fuzz: solve paths x warm starts x corpus")
    ap.add_argument("--scale", default="mini",
                    choices=["mini", "tiny", "small", "large"])
    ap.add_argument("--paths", default="",
                    help="comma-separated solve paths (default: all)")
    ap.add_argument("--warm-starts", default=",".join(DEFAULT_WARM_STARTS))
    ap.add_argument("--families", default="",
                    help="comma-separated families (default: all + mtx)")
    ap.add_argument("--no-rcp", action="store_true")
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--budget", type=int, default=0,
                    help="max cells to run (0 = the full matrix)")
    ap.add_argument("--artifact-dir", default=".")
    ap.add_argument("--minimize-budget", type=int, default=64)
    ap.add_argument("--oracle", default="maximum",
                    choices=["maximum", "maximal"],
                    help="maximal = degraded-mode gate: valid + maximal + "
                         "card <= HK optimum (use with --max-phases)")
    ap.add_argument("--max-phases", type=int, default=0,
                    help="phase budget for the base config (0 = unlimited); "
                         "implies degrade_maximal when --oracle maximal")
    args = ap.parse_args(argv)
    base = MatcherConfig()
    if args.max_phases:
        base = dataclasses.replace(
            base, max_phases=args.max_phases,
            degrade_maximal=args.oracle == "maximal")
    report = verify_corpus(
        scale=args.scale,
        paths=args.paths.split(",") if args.paths else None,
        warm_starts=tuple(args.warm_starts.split(",")),
        rcp=not args.no_rcp, seed=args.seed,
        families=args.families.split(",") if args.families else None,
        base=base,
        artifact_dir=args.artifact_dir,
        budget=args.budget or None,
        minimize_budget=args.minimize_budget,
        oracle=args.oracle)
    print(report.summary(), flush=True)
    return 1 if report.failures else 0


if __name__ == "__main__":
    sys.exit(main())
