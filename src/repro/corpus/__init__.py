"""Corpus-driven differential verification (ISSUE 7).

Two pieces, both enumerating the same registered surfaces instead of
hand-rolled lists:

* :mod:`repro.corpus.verify` — the differential fuzz harness: every
  registered solve path (:data:`repro.matching.SOLVE_PATHS`) × warm-start
  config over every corpus family (original + RCP), cardinality checked
  against the host Hopcroft-Karp oracle, with a minimized failing-edge-list
  artifact dumped on mismatch.  ``python -m repro.corpus.verify`` is the CLI.
* :mod:`repro.corpus.heuristic` — a deterministic replay of the
  direction-optimizing push/pull decisions with a documented work model, so
  the dirop ``alpha``/``beta`` defaults are gateable per family without
  timing flake (``benchmarks/corpus.py`` feeds it into the perf gate).
"""
from .heuristic import (PULL_STREAM_FRACTION, modelled_rel, sweep_grid,
                        trace_instance)
from .verify import (CellResult, FuzzReport, corpus_instances,
                     minimize_failing_edges, oracle_cardinality,
                     verify_corpus)

__all__ = [
    "CellResult", "FuzzReport", "corpus_instances", "minimize_failing_edges",
    "oracle_cardinality", "verify_corpus",
    "PULL_STREAM_FRACTION", "modelled_rel", "sweep_grid", "trace_instance",
]
