"""Correctness of the paper's matcher variants (unit tests).

Hypothesis-based property tests live in test_matching_properties.py, which
skips itself when `hypothesis` is not installed (it is a dev extra, see
pyproject.toml).
"""
import pytest

from repro.core import (MatcherConfig, VARIANTS, cheap_matching,
                        cheap_matching_jax, hopcroft_karp,
                        maximum_cardinality, maximum_matching, pfp,
                        validate_matching)
from repro.graphs import grid_graph, kron_graph, random_bipartite, scaled_free

CONFIGS = [
    MatcherConfig(algo="apfb", kernel="gpubfs"),
    MatcherConfig(algo="apfb", kernel="gpubfs_wr"),
    MatcherConfig(algo="apsb", kernel="gpubfs"),
    MatcherConfig(algo="apsb", kernel="gpubfs_wr", wr_exact=True),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
@pytest.mark.parametrize("gname,g", [
    ("rand", random_bipartite(300, 300, 3.0, seed=1)),
    ("rand_rect", random_bipartite(200, 350, 4.0, seed=2)),
    ("grid", grid_graph(14)),
    ("kron", kron_graph(8, 6, seed=3)),
    ("free", scaled_free(250, 250, 5.0, seed=4)),
    ("perm", random_bipartite(300, 300, 3.0, seed=5).permuted(1)),
])
def test_matcher_reaches_maximum(cfg, gname, g):
    opt = maximum_cardinality(g)
    cm0, rm0 = cheap_matching_jax(g)
    cm, rm, stats = maximum_matching(g, cfg, cm0, rm0)
    card = validate_matching(g, cm, rm)
    assert card == opt, (gname, cfg.name, stats)


def test_oracles_agree():
    for seed in range(5):
        g = random_bipartite(150, 150, 2.5, seed=seed)
        opt = maximum_cardinality(g)
        cm, rm = hopcroft_karp(g)
        assert validate_matching(g, cm, rm) == opt
        cm, rm = pfp(g)
        assert validate_matching(g, cm, rm) == opt


def test_cheap_matching_valid():
    g = random_bipartite(200, 200, 3.0, seed=7)
    c1 = validate_matching(g, *cheap_matching(g))
    c2 = validate_matching(g, *cheap_matching_jax(g))
    opt = maximum_cardinality(g)
    # greedy guarantees >= 1/2 of optimal (maximal matching property)
    assert c1 * 2 >= opt and c2 * 2 >= opt


def test_cold_start_no_warm_init():
    g = random_bipartite(120, 120, 3.0, seed=9)
    cm, rm, _ = maximum_matching(g, MatcherConfig())
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_all_eight_variants_run():
    g = random_bipartite(100, 100, 3.0, seed=11)
    opt = maximum_cardinality(g)
    for cfg in VARIANTS:
        cm, rm, _ = maximum_matching(g, cfg)
        assert validate_matching(g, cm, rm) == opt, cfg.name


def test_push_relabel_oracle():
    """The paper's second algorithm class reaches maximum cardinality."""
    from repro.core import push_relabel
    for seed in range(4):
        g = random_bipartite(200, 200, 3.0, seed=seed)
        cm, rm = push_relabel(g)
        assert validate_matching(g, cm, rm) == maximum_cardinality(g)
    g = grid_graph(12)
    cm, rm = push_relabel(g)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_karp_sipser_init():
    """KS init is a valid matching and (weakly) beats cheap on the suite."""
    from repro.core import karp_sipser_jax
    from repro.graphs import instance_sets
    total_ks = total_cheap = 0
    for name, g in instance_sets("tiny").items():
        cm, rm = karp_sipser_jax(g)
        card = validate_matching(g, cm, rm)
        cheap = validate_matching(g, *cheap_matching_jax(g))
        total_ks += card
        total_cheap += cheap
        assert card * 2 >= maximum_cardinality(g)        # maximal >= opt/2
    assert total_ks >= total_cheap, (total_ks, total_cheap)
