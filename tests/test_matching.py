"""Correctness of the paper's matcher variants: unit + hypothesis property."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BipartiteCSR, MatcherConfig, VARIANTS,
                        cheap_matching, cheap_matching_jax, hopcroft_karp,
                        maximum_cardinality, maximum_matching, pfp,
                        validate_matching)
from repro.graphs import grid_graph, kron_graph, random_bipartite, scaled_free

CONFIGS = [
    MatcherConfig(algo="apfb", kernel="gpubfs"),
    MatcherConfig(algo="apfb", kernel="gpubfs_wr"),
    MatcherConfig(algo="apsb", kernel="gpubfs"),
    MatcherConfig(algo="apsb", kernel="gpubfs_wr", wr_exact=True),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
@pytest.mark.parametrize("gname,g", [
    ("rand", random_bipartite(300, 300, 3.0, seed=1)),
    ("rand_rect", random_bipartite(200, 350, 4.0, seed=2)),
    ("grid", grid_graph(14)),
    ("kron", kron_graph(8, 6, seed=3)),
    ("free", scaled_free(250, 250, 5.0, seed=4)),
    ("perm", random_bipartite(300, 300, 3.0, seed=5).permuted(1)),
])
def test_matcher_reaches_maximum(cfg, gname, g):
    opt = maximum_cardinality(g)
    cm0, rm0 = cheap_matching_jax(g)
    cm, rm, stats = maximum_matching(g, cfg, cm0, rm0)
    card = validate_matching(g, cm, rm)
    assert card == opt, (gname, cfg.name, stats)


def test_oracles_agree():
    for seed in range(5):
        g = random_bipartite(150, 150, 2.5, seed=seed)
        opt = maximum_cardinality(g)
        cm, rm = hopcroft_karp(g)
        assert validate_matching(g, cm, rm) == opt
        cm, rm = pfp(g)
        assert validate_matching(g, cm, rm) == opt


def test_cheap_matching_valid():
    g = random_bipartite(200, 200, 3.0, seed=7)
    c1 = validate_matching(g, *cheap_matching(g))
    c2 = validate_matching(g, *cheap_matching_jax(g))
    opt = maximum_cardinality(g)
    # greedy guarantees >= 1/2 of optimal (maximal matching property)
    assert c1 * 2 >= opt and c2 * 2 >= opt


def test_cold_start_no_warm_init():
    g = random_bipartite(120, 120, 3.0, seed=9)
    cm, rm, _ = maximum_matching(g, MatcherConfig())
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_all_eight_variants_run():
    g = random_bipartite(100, 100, 3.0, seed=11)
    opt = maximum_cardinality(g)
    for cfg in VARIANTS:
        cm, rm, _ = maximum_matching(g, cfg)
        assert validate_matching(g, cm, rm) == opt, cfg.name


@st.composite
def bip_graphs(draw):
    nc = draw(st.integers(1, 60))
    nr = draw(st.integers(1, 60))
    nnz = draw(st.integers(1, 240))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, nc, size=nnz)
    rows = rng.integers(0, nr, size=nnz)
    return BipartiteCSR.from_edges(cols, rows, nc, nr)


@settings(max_examples=40, deadline=None)
@given(g=bip_graphs(),
       variant=st.sampled_from(range(len(CONFIGS))))
def test_property_maximum_and_valid(g, variant):
    """Any random bipartite graph: result is a VALID matching of MAXIMUM
    cardinality (cardinality is unique even though matchings are not)."""
    cfg = CONFIGS[variant]
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    card = validate_matching(g, cm, rm)
    assert card == opt, stats


@settings(max_examples=15, deadline=None)
@given(g=bip_graphs(), seed=st.integers(0, 100))
def test_property_permutation_invariant_cardinality(g, seed):
    """RCP transform (the paper's second instance set) preserves |M*|."""
    gp = g.permuted(seed)
    assert maximum_cardinality(g) == maximum_cardinality(gp)
    cm, rm, _ = maximum_matching(gp, MatcherConfig())
    assert validate_matching(gp, cm, rm) == maximum_cardinality(g)


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_warm_start_consistent(g):
    """Warm-starting from greedy reaches the same cardinality as cold."""
    cm0, rm0 = cheap_matching_jax(g)
    c_warm, r_warm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, c_warm, r_warm) == maximum_cardinality(g)


@settings(max_examples=25, deadline=None)
@given(g=bip_graphs(), tail=st.integers(1, 6))
def test_property_bounded_tail_reaches_maximum(g, tail):
    """Beyond-paper bounded-tail APFB must still terminate at maximum
    cardinality (the phase-gain guard preserves the invariant)."""
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr", tail_levels=tail)
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    assert validate_matching(g, cm, rm) == opt, stats


def test_push_relabel_oracle():
    """The paper's second algorithm class reaches maximum cardinality."""
    from repro.core import push_relabel
    for seed in range(4):
        g = random_bipartite(200, 200, 3.0, seed=seed)
        cm, rm = push_relabel(g)
        assert validate_matching(g, cm, rm) == maximum_cardinality(g)
    g = grid_graph(12)
    cm, rm = push_relabel(g)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_karp_sipser_init():
    """KS init is a valid matching and (weakly) beats cheap on the suite."""
    from repro.core import karp_sipser_jax
    from repro.graphs import banded, instance_sets
    total_ks = total_cheap = 0
    for name, g in instance_sets("tiny").items():
        cm, rm = karp_sipser_jax(g)
        card = validate_matching(g, cm, rm)
        cheap = validate_matching(g, *cheap_matching_jax(g))
        total_ks += card
        total_cheap += cheap
        assert card * 2 >= maximum_cardinality(g)        # maximal >= opt/2
    assert total_ks >= total_cheap, (total_ks, total_cheap)


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_ks_valid_and_matcher_from_ks(g):
    from repro.core import karp_sipser_jax
    cm0, rm0 = karp_sipser_jax(g)
    validate_matching(g, cm0, rm0)
    cm, rm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)
