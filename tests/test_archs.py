"""Per-arch smoke tests: reduced config, one forward + one train step + one
decode step on CPU; asserts shapes and no NaNs (full configs are exercised
only by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import ShapeCell, make_inputs
from repro.models import build_model
from repro.models.transformer import vocab_padded
from repro.optim import OptConfig, adamw_init
from repro.train import build_serve_step, build_train_step


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, ShapeCell("t", 64, 2, "train"))
    logits, aux = model.forward(params, batch)
    s_text = 64 - (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_text, vocab_padded(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    cache, _ = model.init_cache(2, 64, enc_len=16)
    if cfg.enc_layers:
        cache = model.prefill_encoder(params, cache, batch)
    tok = batch["tokens"][:, :1]
    for pos in range(3):
        lg, cache = model.decode_step(params, cache, tok, jnp.int32(pos))
        assert lg.shape == (2, 1, vocab_padded(cfg))
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(1))
    opt_cfg = OptConfig(lr=1e-3, warmup=1)
    opt_state, _ = adamw_init(params, specs, opt_cfg)
    step = jax.jit(build_train_step(model, opt_cfg))
    batch = make_inputs(cfg, ShapeCell("t", 64, 2, "train"))
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # the same batch twice must reduce loss (params actually update)
    assert float(m2["loss"]) < float(m1["loss"]) + 1e-3
    assert int(o2["step"]) == 2


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mamba2-2.7b",
                                  "zamba2-7b"])
def test_decode_matches_forward(arch):
    """Greedy decode over a prompt must reproduce teacher-forced logits."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full_logits, _ = model.forward(params, {"tokens": tokens})
    cache, _ = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_param_counts_match_analytic():
    from repro.models.common import tree_size
    for arch in ARCH_NAMES:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        actual = tree_size(params)
        analytic = cfg.params_count()
        # analytic formula ignores norms/conv/bias-size terms: allow 15%
        assert abs(actual - analytic) / max(actual, 1) < 0.15, \
            (arch, actual, analytic)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published numbers from the brief."""
    expect = {
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (L, D, H, KV, F, V), (arch, got)
    assert get_config("llama4-maverick-400b-a17b").n_experts == 128
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("mamba2-2.7b").ssm_state == 128


@pytest.mark.parametrize("arch", ["dbrx-132b", "llama4-maverick-400b-a17b"])
def test_perf_opt_flags_parity(arch):
    """§Perf optimization flags must not change model semantics (single
    device: local dispatch degenerates to shards=1; H-flat is exact)."""
    batch = make_inputs(get_config(arch, smoke=True),
                        ShapeCell("t", 64, 2, "train"))
    outs = {}
    for opt in (False, True):
        cfg = get_config(arch, smoke=True, opt_moe_dispatch=opt,
                         opt_attn_layout=opt)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        logits, _ = model.forward(params, batch)
        outs[opt] = np.asarray(logits, np.float32)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-3, rtol=1e-3)
