"""Distributed (shard_map) matcher — the paper's future-work algorithm —
runs in a subprocess with 8 simulated devices."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import jax, numpy as np
from repro.core import (MatcherConfig, cheap_matching_jax,
                        maximum_cardinality, validate_matching)
from repro.core.distributed import maximum_matching_distributed
from repro.graphs import grid_graph, random_bipartite, scaled_free

mesh = jax.make_mesh((8,), ("data",))
cases = {
    "rand": random_bipartite(500, 500, 4.0, seed=2),
    "grid": grid_graph(18),
    "rect": random_bipartite(300, 450, 3.0, seed=3),
    "free": scaled_free(400, 400, 5.0, seed=4).permuted(1),
}
for name, g in cases.items():
    opt = maximum_cardinality(g)
    cm0, rm0 = cheap_matching_jax(g)
    for algo in ("apfb", "apsb"):
        cfg = MatcherConfig(algo=algo, kernel="gpubfs_wr")
        cm, rm, st = maximum_matching_distributed(
            g, mesh, cfg, cmatch0=cm0, rmatch0=rm0)
        card = validate_matching(g, cm, rm)
        assert card == opt, (name, algo, card, opt)
print("DIST_OK")
"""


def test_distributed_matcher_8dev():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=f"{REPO}/src")
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=580)
    assert "DIST_OK" in r.stdout, r.stderr[-3000:]
