"""ShardedMatcher (shard_map, one pmin per BFS level) on a forced 4-device
CPU host.

Each scenario runs in a subprocess because the forced device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) must be set before
JAX initializes, and the rest of the suite runs single-device.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = """
import jax, numpy as np
from repro.core import (MatcherConfig, maximum_cardinality, validate_matching)
from repro.graphs import grid_graph, random_bipartite, scaled_free
from repro.matching import (DeviceCSR, Matcher, ShardedMatcher,
                            compile_cache_info)

assert jax.device_count() == 4, jax.device_count()
mesh = jax.make_mesh((4,), ("data",))
cases = {
    "rand": random_bipartite(500, 500, 4.0, seed=2),
    "grid": grid_graph(18),                       # adversarial: long paths
    "rect": random_bipartite(300, 450, 3.0, seed=3),
    "free": scaled_free(400, 400, 5.0, seed=4).permuted(1),  # skewed degrees
}
"""

# ShardedMatcher == single-device Matcher.run cardinality (== optimal),
# across the generator suite, per algo / warm start.
EQUALITY = PRELUDE + """
for name, g in cases.items():
    opt = maximum_cardinality(g)
    graph = DeviceCSR.from_host(g)
    sharded_g = graph.shard(mesh, "data")
    for algo in ("apfb", "apsb"):
        cfg = MatcherConfig(algo=algo, kernel="gpubfs_wr")
        single = Matcher(cfg, warm_start="cheap").run(graph)
        st = ShardedMatcher(mesh, config=cfg, warm_start="cheap").run(sharded_g)
        cm, rm = st.to_host()
        card = validate_matching(g, cm, rm)
        assert card == opt == int(single.cardinality), \\
            (name, algo, card, opt, int(single.cardinality))
print("DIST_OK")
"""

# Repeated same-bucket sharded calls must hit the compile cache, and a second
# mesh axis name / different bucket must miss.
CACHE = PRELUDE + """
g = cases["rand"]
sharded_g = DeviceCSR.from_host(g).shard(mesh, "data")
m = ShardedMatcher(mesh, config=MatcherConfig(), warm_start="cheap")
c0 = int(m.run(sharded_g).cardinality)
info1 = compile_cache_info()
c1 = int(m.run(sharded_g).cardinality)
info2 = compile_cache_info()
assert c0 == c1
assert info2["misses"] == info1["misses"], (info1, info2)   # no recompile
assert info2["hits"] == info1["hits"] + 1, (info1, info2)
g2 = cases["grid"]                                          # other bucket
m.run(DeviceCSR.from_host(g2).shard(mesh, "data"))
info3 = compile_cache_info()
assert info3["misses"] == info2["misses"] + 1, (info2, info3)
print("DIST_OK")
"""

# The fused Pallas frontier kernel as the per-shard sweep: each shard's
# winner merge happens inside its kernel, one pmin merges the shards, and
# the result must be BIT-identical to the single-device jnp path (the
# deterministic min-merge makes every sweep path interchangeable).
PALLAS = PRELUDE + """
import dataclasses
g = cases["rand"]
opt = maximum_cardinality(g)
graph = DeviceCSR.from_host(g)
sharded_g = graph.shard(mesh, "data")
for schedule in ("ct", "mt"):
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule=schedule,
                        use_pallas=True)
    single = Matcher(dataclasses.replace(cfg, use_pallas=False),
                     warm_start="cheap").run(graph)
    for fused in (True, False):
        fcfg = dataclasses.replace(cfg, pallas_fused=fused)
        st = ShardedMatcher(mesh, config=fcfg, warm_start="cheap").run(sharded_g)
        cm, rm = st.to_host()
        assert validate_matching(g, cm, rm) == opt, (schedule, fused)
        np.testing.assert_array_equal(np.asarray(st.cmatch),
                                      np.asarray(single.cmatch))
        np.testing.assert_array_equal(np.asarray(st.rmatch),
                                      np.asarray(single.rmatch))
print("DIST_OK")
"""

# Direction-optimizing engine on the sharded path: each shard pulls over
# its own CSC slice (jnp stream or the Pallas pull kernel), the one pmin
# still merges, and the result must be BIT-identical to the single-device
# jnp path across algos.  Also: the mirror must be attached before shard().
DIROP = PRELUDE + """
import dataclasses
g = cases["rand"]
opt = maximum_cardinality(g)
graph = DeviceCSR.from_host(g)
sharded_g = graph.with_csc().shard(mesh, "data")
for algo in ("apfb", "apsb"):
    for use_pallas in (False, True):
        cfg = MatcherConfig(algo=algo, kernel="gpubfs_wr", dirop=True,
                            use_pallas=use_pallas)
        single = Matcher(dataclasses.replace(cfg, dirop=False,
                                             use_pallas=False),
                         warm_start="cheap").run(graph)
        st = ShardedMatcher(mesh, config=cfg, warm_start="cheap").run(sharded_g)
        cm, rm = st.to_host()
        assert validate_matching(g, cm, rm) == opt, (algo, use_pallas)
        np.testing.assert_array_equal(np.asarray(st.cmatch),
                                      np.asarray(single.cmatch))
        np.testing.assert_array_equal(np.asarray(st.rmatch),
                                      np.asarray(single.rmatch))
try:
    ShardedMatcher(mesh, config=MatcherConfig(dirop=True)).run(
        DeviceCSR.from_host(g).shard(mesh, "data"))
except ValueError as e:
    assert "with_csc" in str(e), e
else:
    raise AssertionError("missing mirror must be a typed error")
print("DIST_OK")
"""

# The numpy-compat wrapper (old core.distributed surface) and warm-state
# resume via cmatch0/rmatch0.
COMPAT = PRELUDE + """
from repro.core import cheap_matching_jax
from repro.core.distributed import maximum_matching_distributed
g = cases["rect"]
opt = maximum_cardinality(g)
cm0, rm0 = cheap_matching_jax(g)
for algo in ("apfb", "apsb"):
    cfg = MatcherConfig(algo=algo, kernel="gpubfs_wr")
    cm, rm, st = maximum_matching_distributed(g, mesh, cfg,
                                              cmatch0=cm0, rmatch0=rm0)
    assert validate_matching(g, cm, rm) == opt, (algo, st)
    assert st["devices"] == 4 and st["variant"].startswith("dist-")
print("DIST_OK")
"""

SCENARIOS = {"equality": EQUALITY, "cache": CACHE, "pallas": PALLAS,
             "dirop": DIROP, "compat": COMPAT}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_sharded_matcher_4dev(scenario):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=f"{REPO}/src")
    r = subprocess.run([sys.executable, "-c", SCENARIOS[scenario]], env=env,
                       capture_output=True, text=True, timeout=580)
    assert "DIST_OK" in r.stdout, r.stderr[-3000:]
