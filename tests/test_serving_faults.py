"""Chaos matrix for the fault-tolerant serving stack.

Every rung of the failure model / degradation ladder documented in
``docs/architecture.md`` — validate, quarantine, shed, degrade, restart —
driven deterministically through :class:`repro.serving.FaultInjector`:

* poisoned-batch bisection isolates exactly the bad request (innocents
  succeed; the poison fails with the real error + a quarantine artifact);
* a transient dispatch fault is absorbed by the singleton retry;
* flush-thread death -> supervisor fails the in-flight futures with
  :class:`FlushThreadDiedError`, restarts the thread, later submits served;
* deadline-miss shedding at flush time; bounded-queue shed policies
  (reject-newest backpressure / reject-oldest eviction) under sustained
  overload, with the metrics sum invariant
  ``submitted == completed + failed + cancelled + shed_oldest +
  deadline_misses`` holding throughout;
* ``max_phases`` degradation returns a valid *maximal* matching with
  ``certified == False`` and a full-budget rerun matches the
  Hopcroft-Karp oracle — also sweepable over every registered solve path
  via the corpus harness's ``oracle="maximal"`` mode;
* ``close()`` never strands a future (pending requests fail with
  :class:`ServiceClosedError`).
"""
import dataclasses
import json
import os

import pytest

from repro.core import is_maximal, validate_matching
from repro.core.oracles import hopcroft_karp
from repro.graphs import random_bipartite
from repro.matching import GraphValidationError, MatcherConfig
from repro.serving import (Bucketizer, DeadlineExceededError, FaultInjector,
                           FlushThreadDiedError, MatchingService,
                           PoisonedGraphFault, QueueFullError,
                           ServiceClosedError, SheddedError, SizeBucket)

CFG = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
BUCKET = SizeBucket(256, 256, 2048)


def graphs(n, seed0=100):
    return [random_bipartite(180 + i, 170 + i, 3.0, seed=seed0 + i)
            for i in range(n)]


def make_service(**kw):
    kw.setdefault("bucketizer", Bucketizer((BUCKET,), validate=True))
    kw.setdefault("config", CFG)
    kw.setdefault("warm_start", "cheap")
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay_ms", 60.0)
    kw.setdefault("adaptive", False)
    kw.setdefault("supervisor_interval_s", 0.02)
    return MatchingService(**kw)


def check_sum_invariant(snap):
    """Every accepted request is accounted for exactly once."""
    assert snap["submitted"] == (snap["completed"] + snap["failed"]
                                 + snap["cancelled"] + snap["shed_oldest"]
                                 + snap["deadline_misses"]), snap


# ---------------------------------------------------------------------------
# validate: structural admission checks
# ---------------------------------------------------------------------------
def test_admission_rejects_structurally_corrupt_graph():
    g = graphs(1)[0]
    bad_cadj = g.cadj.copy()
    bad_cadj[0] = g.nr + 5                       # row endpoint out of range
    bad = dataclasses.replace(g, cadj=bad_cadj)
    with make_service() as svc:
        with pytest.raises(GraphValidationError) as ei:
            svc.submit(bad)
        assert any("cadj" in p for p in ei.value.problems)
        res = svc.submit(g).result(timeout=300)  # service unharmed
        snap = svc.metrics.snapshot()
    assert res.cardinality > 0
    assert snap["rejected"] == 1 and snap["submitted"] == 1


# ---------------------------------------------------------------------------
# quarantine: bisection isolates the poison, innocents succeed
# ---------------------------------------------------------------------------
def test_bisection_isolates_exactly_the_poisoned_request(tmp_path):
    faults = FaultInjector(seed=3)
    faults.poison("bad")
    gs = graphs(4)
    with make_service(faults=faults, quarantine_dir=str(tmp_path)) as svc:
        futs = [svc.submit(g, tag="bad" if i == 2 else f"ok{i}")
                for i, g in enumerate(gs)]
        svc.drain()
        snap = svc.metrics.snapshot()
    for i, fut in enumerate(futs):
        if i == 2:
            continue
        res = fut.result(timeout=300)            # innocents all served
        cm, rm = res.matching()
        assert validate_matching(gs[i], cm, rm) == res.cardinality
    exc = futs[2].exception(timeout=300)
    assert isinstance(exc, PoisonedGraphFault) and exc.tag == "bad"
    # the isolated request left a replayable artifact
    art = exc.quarantine_artifact
    assert art and os.path.exists(art)
    with open(art) as f:
        payload = json.load(f)
    assert payload["schema"] == "repro-serving-quarantine/1"
    assert payload["tag"] == "bad"
    assert payload["nnz"] == len(payload["edges"]) == gs[2].nnz
    assert snap["quarantined"] == 1 and snap["failed"] == 1
    assert snap["completed"] == 3
    check_sum_invariant(snap)


def test_singleton_retry_absorbs_transient_fault():
    faults = FaultInjector(seed=4)
    faults.script(RuntimeError("transient device hiccup"))
    g = graphs(1)[0]
    with make_service(faults=faults, max_delay_ms=5.0,
                      dispatch_retries=2) as svc:
        res = svc.submit(g).result(timeout=300)
        snap = svc.metrics.snapshot()
    assert res.cardinality > 0
    assert snap["quarantined"] == 0 and snap["failed"] == 0
    assert faults.injected == 1                  # the fault did fire


# ---------------------------------------------------------------------------
# restart: flush-thread death -> supervisor fail-over + restart
# ---------------------------------------------------------------------------
def test_thread_death_supervisor_restarts_and_serves():
    faults = FaultInjector(seed=5)
    gs = graphs(4)
    with make_service(faults=faults) as svc:
        faults.kill_thread_after(0)              # very next dispatch dies
        futs = [svc.submit(g) for g in gs]
        excs = [f.exception(timeout=300) for f in futs]
        died = [e for e in excs if isinstance(e, FlushThreadDiedError)]
        assert died, excs                        # in-flight failed over
        assert all(e is None or isinstance(e, FlushThreadDiedError)
                   for e in excs)
        res = svc.submit(gs[0]).result(timeout=300)   # post-restart service
        snap = svc.metrics.snapshot()
    assert res.cardinality > 0
    assert snap["restarts"] >= 1 and faults.kills == 1
    check_sum_invariant(snap)


def test_close_fails_pending_futures_when_thread_is_dead():
    faults = FaultInjector(seed=6)
    gs = graphs(2)
    svc = make_service(faults=faults, supervise=False)   # nobody restarts
    faults.kill_thread_after(0)
    futs = [svc.submit(g) for g in gs[:2]]
    svc.flush()
    svc._thread.join(timeout=60)                 # let the injected crash land
    assert not svc._thread.is_alive()
    svc.close()                                  # must not strand the futures
    excs = [f.exception(timeout=60) for f in futs]
    assert all(isinstance(e, ServiceClosedError) for e in excs), excs


# ---------------------------------------------------------------------------
# shed: deadlines and bounded-queue policies under overload
# ---------------------------------------------------------------------------
def test_deadline_miss_is_shed_at_flush_time():
    g1, g2 = graphs(2)
    with make_service(max_delay_ms=5.0) as svc:
        late = svc.submit(g1, deadline_s=0.0)    # expired before any flush
        ok = svc.submit(g2)
        res = ok.result(timeout=300)
        snap = svc.metrics.snapshot()
    assert isinstance(late.exception(timeout=300), DeadlineExceededError)
    assert res.cardinality > 0
    assert snap["deadline_misses"] == 1
    check_sum_invariant(snap)


@pytest.mark.parametrize("policy", ["reject-newest", "reject-oldest"])
def test_shed_policy_under_sustained_overload(policy):
    faults = FaultInjector(seed=7, latency_s=0.08)   # slow device
    gs = graphs(8)
    refused = 0
    futs = []
    with make_service(faults=faults, max_batch=1, max_delay_ms=1.0,
                      max_queue=2, shed_policy=policy) as svc:
        for g in gs:
            try:
                futs.append(svc.submit(g))
            except QueueFullError:
                refused += 1
        svc.drain()
        snap = svc.metrics.snapshot()
    excs = [f.exception(timeout=300) for f in futs]
    evicted = sum(isinstance(e, SheddedError) for e in excs)
    assert all(e is None or isinstance(e, SheddedError) for e in excs), excs
    if policy == "reject-newest":
        assert refused >= 1 and refused == snap["shed_newest"]
        assert evicted == 0 and snap["shed_oldest"] == 0
        assert snap["submitted"] == len(futs)
    else:
        assert refused == 0 and snap["shed_newest"] == 0
        assert evicted >= 1 and evicted == snap["shed_oldest"]
        assert snap["submitted"] == len(gs)
    check_sum_invariant(snap)


def test_cancelled_future_is_counted():
    g1, g2 = graphs(2)
    with make_service() as svc:                  # 60ms delay: stays queued
        f1 = svc.submit(g1)
        f2 = svc.submit(g2)
        assert f1.cancel()
        assert f2.result(timeout=300).cardinality > 0
        svc.drain()
        snap = svc.metrics.snapshot()
    assert snap["cancelled"] == 1 and snap["completed"] == 1
    check_sum_invariant(snap)


# ---------------------------------------------------------------------------
# degrade: phase budget -> valid maximal matching, certified=False
# ---------------------------------------------------------------------------
def test_phase_budget_degrades_to_certified_false_maximal():
    g = random_bipartite(220, 200, 3.0, seed=42)
    budget = dataclasses.replace(CFG, max_phases=1, degrade_maximal=True)
    with make_service(max_delay_ms=5.0) as svc:
        degraded = svc.submit(g, config=budget, warm_start="none"
                              ).result(timeout=300)
        full = svc.submit(g).result(timeout=300)
    assert not degraded.certified                # budget truncated the solve
    cm, rm = degraded.matching()
    card = validate_matching(g, cm, rm)          # still a valid matching...
    assert is_maximal(g, cm, rm)                 # ...and maximal (>= M*/2)
    assert card == degraded.cardinality
    # the full-budget rerun certifies and matches the host HK oracle
    assert full.certified
    hk_cm, hk_rm = hopcroft_karp(g)
    assert full.cardinality == validate_matching(g, hk_cm, hk_rm)
    assert card <= full.cardinality
    assert 2 * card >= full.cardinality          # the maximal-matching bound


def test_corpus_harness_maximal_oracle_under_phase_budget(tmp_path):
    """Every registered solve path stays valid + maximal at max_phases=1
    (the acceptance sweep; CI's chaos-smoke job runs a bigger budget)."""
    from repro.corpus.verify import verify_corpus
    base = MatcherConfig(max_phases=1, degrade_maximal=True)
    rep = verify_corpus(scale="mini", budget=6, rcp=False, minimize=False,
                        base=base, oracle="maximal",
                        artifact_dir=str(tmp_path))
    assert not rep.failures, rep.summary()
