"""Docs can't rot: execute every python snippet in README.md + docs/*.md and
check intra-repo links.

Each fenced ```python block runs in its own subprocess on a forced 4-device
CPU host (so multi-device snippets are exercised for real), with the repo's
``src/`` on PYTHONPATH.  A snippet that should not be executed has no place
in the docs — keep them small and runnable.
"""
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = [os.path.join(REPO, "README.md")] + sorted(
    os.path.join(REPO, "docs", f)
    for f in (os.listdir(os.path.join(REPO, "docs"))
              if os.path.isdir(os.path.join(REPO, "docs")) else [])
    if f.endswith(".md"))

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _snippets():
    out = []
    for path in DOC_FILES:
        text = open(path).read()
        for i, m in enumerate(_FENCE.finditer(text)):
            out.append(pytest.param(path, m.group(1),
                                    id=f"{os.path.relpath(path, REPO)}:{i}"))
    return out


@pytest.mark.parametrize("path,code", _snippets())
def test_doc_snippet_runs(path, code):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=f"{REPO}/src")
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=580)
    assert r.returncode == 0, f"snippet in {path} failed:\n{r.stderr[-3000:]}"


def test_intra_repo_links_resolve():
    """Every relative markdown link in README/docs points at a real file."""
    broken = []
    for path in DOC_FILES:
        base = os.path.dirname(path)
        for target in _LINK.findall(open(path).read()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not os.path.exists(os.path.join(base, rel)):
                broken.append(f"{path}: {target}")
    assert not broken, "\n".join(broken)


def test_no_dangling_experiments_refs():
    """The old experiments log is gone; nothing may still cite it.
    (Real targets live in docs/architecture.md now.)"""
    needle = "EXPERIMENTS" + ".md"          # don't match this test itself
    offenders = []
    scan_roots = ["src", "benchmarks", "tests", "examples", "docs"]
    files = [os.path.join(REPO, "README.md")]
    for root in scan_roots:
        for dirpath, _, names in os.walk(os.path.join(REPO, root)):
            if "__pycache__" in dirpath:
                continue
            files += [os.path.join(dirpath, n) for n in names
                      if n.endswith((".py", ".md"))]
    for f in files:
        if os.path.abspath(f) == os.path.abspath(__file__):
            continue
        if needle in open(f, errors="ignore").read():
            offenders.append(os.path.relpath(f, REPO))
    assert not offenders, offenders
