"""The loop-aware HLO cost model must match analytic FLOPs on known programs
(this is the correction on top of xla's HloCostAnalysis, which counts while
bodies once — see launch/hlo_cost.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 512))
    got = analyze(_hlo(lambda a, b: a @ b, a, b))
    expect = 2 * 128 * 256 * 512
    assert abs(got["flops"] - expect) / expect < 0.01
    assert got["unknown_while"] == 0


def test_scan_multiplies_by_trip_count():
    w = jnp.zeros((8, 64, 64))     # 8 scanned layers

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jnp.zeros((32, 64))
    got = analyze(_hlo(fn, x, w))
    expect = 8 * 2 * 32 * 64 * 64
    assert abs(got["flops"] - expect) / expect < 0.05, got["flops"] / expect


def test_nested_scan():
    w = jnp.zeros((4, 3, 32, 32))

    def fn(x, w):
        def outer(c, wg):
            def inner(c2, wi):
                return c2 @ wi, None
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jnp.zeros((16, 32))
    got = analyze(_hlo(fn, x, w))
    expect = 12 * 2 * 16 * 32 * 32
    assert abs(got["flops"] - expect) / expect < 0.05, got["flops"] / expect


def test_batched_dot_flops():
    a = jnp.zeros((4, 64, 96))
    b = jnp.zeros((4, 96, 32))
    got = analyze(_hlo(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b))
    expect = 2 * 4 * 64 * 96 * 32
    assert abs(got["flops"] - expect) / expect < 0.01


def test_bytes_scale_with_scan():
    w = jnp.zeros((16, 128, 128))

    def fn(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    x = jnp.zeros((4, 128))
    got = analyze(_hlo(fn, x, w))
    # each iteration must read at least one (128,128) f32 weight slice
    assert got["bytes"] >= 16 * 128 * 128 * 4
