"""End-to-end behaviour: training learns, serving generates, the matching
system solves the paper's workload end-to-end, optimizer semantics hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MatcherConfig, cheap_matching_jax,
                        maximum_cardinality, maximum_matching,
                        validate_matching)
from repro.data import DataConfig, synthetic_batch
from repro.graphs import instance_sets
from repro.models import build_model
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.train import build_train_step, cross_entropy


def test_training_learns_structured_data():
    """~80 steps on the copy-structured stream must cut loss well below
    ln(V)~6.2 (the run reaches ~2.5 by step 60; see examples/train_lm.py)."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-2, warmup=10, weight_decay=0.0)
    opt, _ = adamw_init(params, specs, opt_cfg)
    step = jax.jit(build_train_step(model, opt_cfg))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16)
    first = last = None
    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, i).items()}
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.5, (first, last)


def test_microbatched_train_step_matches():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-3, warmup=1)
    opt, _ = adamw_init(params, specs, opt_cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, 0).items()}
    s1 = jax.jit(build_train_step(model, opt_cfg))
    s4 = jax.jit(build_train_step(model, opt_cfg, microbatch=4))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_serve_generates():
    from repro.launch.serve import run
    out = run("mamba2-2.7b", smoke=True, batch=2, prompt_len=8, gen=8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < 512).all()


def test_matching_end_to_end_instance_suite():
    """The paper's workload: full tiny instance suite, original + RCP."""
    best = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
    for name, g in instance_sets("tiny").items():
        for tag, gg in (("orig", g), ("rcp", g.permuted(13))):
            opt = maximum_cardinality(gg)
            cm0, rm0 = cheap_matching_jax(gg)
            cm, rm, st = maximum_matching(gg, best, cm0, rm0)
            assert validate_matching(gg, cm, rm) == opt, (name, tag, st)


def test_cross_entropy_chunked_matches_plain():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 128, 50))
    labels = jax.random.randint(key, (2, 128), 0, 50)
    a = cross_entropy(logits, labels, chunk=1024)   # plain path
    b = cross_entropy(logits, labels, chunk=32)     # chunked path
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_adamw_factored_close_to_full():
    """Factored AdamW must track full AdamW directionally on a quadratic."""
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (16, 16))
    params = {"w": jnp.zeros((16, 16))}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - W))

    outs = {}
    for factored in (False, True):
        cfg = OptConfig(lr=0.05, warmup=1, factored=factored,
                        master_fp32=not factored, weight_decay=0.0)
        p = params
        st, _ = adamw_init(p, {"w": jax.sharding.PartitionSpec()}, cfg)
        for _ in range(60):
            g = jax.grad(loss)(p)
            p, st, _ = adamw_update(p, g, st, cfg)
        outs[factored] = loss(p)
    assert float(outs[True]) < float(loss(params)) * 0.05
    assert float(outs[False]) < float(loss(params)) * 0.05


def test_pallas_matcher_agrees_with_jnp_matcher():
    """use_pallas=True must give identical matchings phase-for-phase."""
    from repro.graphs import random_bipartite
    g = random_bipartite(800, 800, 4.0, seed=5, pad_to=4096)
    cm0, rm0 = cheap_matching_jax(g)
    cfgj = MatcherConfig(algo="apfb", kernel="gpubfs_wr", use_pallas=False)
    cfgp = MatcherConfig(algo="apfb", kernel="gpubfs_wr", use_pallas=True)
    cmj, rmj, stj = maximum_matching(g, cfgj, cm0, rm0)
    cmp_, rmp, stp = maximum_matching(g, cfgp, cm0, rm0)
    np.testing.assert_array_equal(cmj, cmp_)
    np.testing.assert_array_equal(rmj, rmp)
    assert stj["phases"] == stp["phases"]
