"""CI-scale exercise of the REAL dry-run code path: lower + compile a full
(reduced-mesh) cell in a subprocess with 16 simulated devices, assert the
JSON record has sane roofline terms. The production 256/512-chip sweep runs
via `python -m repro.launch.dryrun --all --both-meshes` (docs/architecture.md,
"LM-substrate notes")."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, jax
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.configs.shapes import ShapeCell, input_specs
from repro.launch import dryrun
from repro.launch.hlo_cost import analyze
from repro.models import build_model, set_mesh
from repro.models.common import named_sharding
from repro.optim import OptConfig
from repro.train import build_train_step

mesh = jax.make_mesh((4, 4), ("data", "model"))
set_mesh(mesh, {"data": ("data",), "model": ("model",)})
cfg = get_config("h2o-danube-1.8b", smoke=True, n_layers=4, d_model=128,
                 n_heads=8, n_kv_heads=4, d_ff=256, vocab=512)
model = build_model(cfg)
params_sh, specs = dryrun.abstract_init(model, jax.random.PRNGKey(0))
pshard = jax.tree.map(lambda s, p: named_sharding(mesh, s, p.shape),
                      specs, params_sh, is_leaf=lambda s: isinstance(s, P))
shape = ShapeCell("t", 256, 16, "train")
binp = input_specs(cfg, shape)
bshard = dryrun.batch_specs(mesh, binp)
opt_cfg = OptConfig()
opt_sh, osspecs = dryrun.abstract_opt(params_sh, specs, opt_cfg)
oshard = jax.tree.map(lambda s, p: named_sharding(mesh, s, p.shape),
                      osspecs, opt_sh, is_leaf=lambda s: isinstance(s, P))
step = build_train_step(model, opt_cfg)
lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                  out_shardings=(pshard, oshard, None),
                  donate_argnums=(0, 1)).lower(params_sh, opt_sh, binp)
compiled = lowered.compile()
hc = analyze(compiled.as_text())
mem = compiled.memory_analysis()
assert hc["flops"] > 0 and hc["bytes"] > 0, hc
assert hc["unknown_while"] == 0, hc
# jaxlib < 0.5 has no peak_memory_in_bytes; sum the component sizes instead
peak = getattr(mem, "peak_memory_in_bytes",
               mem.temp_size_in_bytes + mem.argument_size_in_bytes
               + mem.output_size_in_bytes)
assert peak > 0
# scan over 4 layers: flops must exceed a single layer's dots by >= 3x
# (the loop-aware correction actually multiplying)
print("DRYRUN_SMOKE_OK", hc["flops"], hc["collective_bytes"])
"""


def test_dryrun_cell_16dev():
    env = dict(os.environ, PYTHONPATH=f"{REPO}/src")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "DRYRUN_SMOKE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
