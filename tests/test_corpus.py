"""repro.corpus (ISSUE 7): instance families, the mtx fixture, the
differential fuzz harness (solve paths x warm starts x families), the
failure-artifact minimizer, and the per-family dirop heuristic gate."""
import dataclasses
import functools
import json

import numpy as np
import pytest

from repro.core import (MatcherConfig, cheap_matching, hopcroft_karp,
                        maximum_cardinality, pfp, push_relabel,
                        validate_matching)
from repro.corpus import corpus_instances, verify_corpus
from repro.corpus.heuristic import modelled_rel, sweep_grid, trace_instance
from repro.corpus.verify import (ARTIFACT_SCHEMA, minimize_failing_edges,
                                 oracle_cardinality, shared_bucket)
from repro.graphs import (INSTANCE_FAMILIES, comb_chain, community_graph,
                          instance_sets, load_mtx, mtx_fixture)
from repro.graphs.mtx import FIXTURE_DIR
from repro.matching import (SOLVE_PATHS, register_solve_path,
                            unregister_solve_path)

CORPUS_FAMILIES = INSTANCE_FAMILIES + ("mtx",)


@functools.lru_cache(maxsize=None)
def _mini():
    return corpus_instances("mini", rcp=True)


# ---------------------------------------------------------------------------
# new instance families: structure
# ---------------------------------------------------------------------------
def test_comb_chain_is_a_bfs_worst_case():
    """The adversarial comb: greedy leaves exactly one free column whose only
    augmenting path alternates down the whole spine, so the solver must run
    O(length) BFS levels — teeth must not shortcut it."""
    L = 64
    g = comb_chain(L, teeth=16, seed=7)
    assert g.nc == L + 1
    opt = maximum_cardinality(g)
    assert opt == L + 1                       # a perfect column matching exists
    cm, rm = cheap_matching(g)
    assert validate_matching(g, cm, rm) == L  # greedy deficiency exactly 1
    tr = trace_instance(g, warm_start="cheap")
    assert tr.levels >= L // 2                # the long path really is walked


def test_comb_chain_teethless_and_rcp():
    g = comb_chain(32, teeth=0, seed=1)
    assert maximum_cardinality(g) == 33
    assert maximum_cardinality(g.permuted(3)) == 33


def test_community_graph_blocks_are_real():
    nc = nr = 192
    blocks = 6
    g = community_graph(nc, nr, blocks=blocks, avg_deg=3.0, p_in=1.0, seed=3)
    assert (g.nc, g.nr) == (nc, nr) and g.nnz > 0
    cols, rows = g.ecol[: g.nnz], g.cadj[: g.nnz]
    cblk = cols.astype(np.int64) * blocks // nc
    # p_in=1.0: every edge stays inside its column's diagonal block
    assert np.all(rows >= cblk * nr // blocks)
    assert np.all(rows < (cblk + 1) * nr // blocks)
    mixed = community_graph(nc, nr, blocks=blocks, avg_deg=3.0, p_in=0.5,
                            seed=3)
    blk = (mixed.ecol[: mixed.nnz].astype(np.int64) * blocks // nc)
    inside = ((mixed.cadj[: mixed.nnz] >= blk * nr // blocks)
              & (mixed.cadj[: mixed.nnz] < (blk + 1) * nr // blocks))
    assert 0 < inside.sum() < mixed.nnz       # p_in<1 actually mixes


def test_mtx_fixture_loads_committed_file():
    g = mtx_fixture()
    assert (g.nc, g.nr, g.nnz) == (14, 16, 30)
    assert maximum_cardinality(g) == 10
    g2 = load_mtx(f"{FIXTURE_DIR}/ufl_tiny.mtx")
    np.testing.assert_array_equal(g.ecol[: g.nnz], g2.ecol[: g2.nnz])
    np.testing.assert_array_equal(g.cadj[: g.nnz], g2.cadj[: g2.nnz])
    assert mtx_fixture(pad_to=256).nnz_pad == 256


def test_instance_sets_unified_across_scales():
    """Satellite (a): every scale exposes the SAME family list, and rcp=True
    appends an RCP twin per family with identical maximum cardinality."""
    for scale in ("mini", "tiny"):
        insts = instance_sets(scale)
        assert tuple(insts) == INSTANCE_FAMILIES, scale
    both = instance_sets("mini", rcp=True)
    assert set(both) == (set(INSTANCE_FAMILIES)
                         | {f"{k}_rcp" for k in INSTANCE_FAMILIES})
    for k in INSTANCE_FAMILIES:
        assert (maximum_cardinality(both[k])
                == maximum_cardinality(both[f"{k}_rcp"])), k


# ---------------------------------------------------------------------------
# satellite (b): sequential oracles agree across the corpus
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rcp", ["orig", "rcp"])
@pytest.mark.parametrize("family", CORPUS_FAMILIES)
def test_oracles_agree_on_cardinality(family, rcp):
    g = _mini()[family if rcp == "orig" else f"{family}_rcp"]
    opt = maximum_cardinality(g)              # scipy's C Hopcroft-Karp
    for oracle in (hopcroft_karp, pfp, push_relabel):
        cm, rm = oracle(g)
        assert validate_matching(g, cm, rm) == opt, oracle.__name__


# ---------------------------------------------------------------------------
# tentpole: the differential fuzz harness
# ---------------------------------------------------------------------------
def test_corpus_instances_and_shared_bucket():
    insts = _mini()
    assert len(insts) == 2 * len(CORPUS_FAMILIES)
    nc, nr, cap = shared_bucket(insts.values())
    assert all(g.nc <= nc and g.nr <= nr and g.nnz_pad <= cap
               for g in insts.values())
    assert oracle_cardinality(insts["mtx"]) == 10
    sub = corpus_instances("mini", families=("rand", "comb"))
    assert set(sub) == {"rand", "comb", "rand_rcp", "comb_rcp"}


def test_fuzz_smoke_two_paths(tmp_path):
    report = verify_corpus(scale="mini", paths=("jnp", "dirop"),
                           warm_starts=("cheap",),
                           families=("rand", "comb", "mtx"),
                           artifact_dir=str(tmp_path))
    assert len(report.results) == 3 * 2 * 2   # families x rcp x paths
    assert not report.failures, report.summary()
    assert "12/12 cells ok" in report.summary()


def test_fuzz_budget_rotates_path_coverage(tmp_path):
    report = verify_corpus(scale="mini", warm_starts=("cheap",),
                           families=("rand", "sparse", "grid", "comb",
                                     "band", "kron", "free"),
                           rcp=False, budget=7, artifact_dir=str(tmp_path))
    assert not report.failures, report.summary()
    # one cell per instance, path order rotated: all 7 paths under budget 7
    assert {r.path for r in report.results} == set(SOLVE_PATHS)


@pytest.mark.slow
def test_fuzz_full_matrix_mini(tmp_path):
    """Acceptance: every registered solve path x warm start over the full
    mini corpus (orig + RCP), cardinality == the Hopcroft-Karp oracle."""
    report = verify_corpus(scale="mini", artifact_dir=str(tmp_path))
    assert len(report.results) == (2 * len(CORPUS_FAMILIES)
                                   * len(SOLVE_PATHS) * 2)
    assert not report.failures, report.summary()


def test_broken_path_dumps_minimized_artifact(tmp_path):
    """A deliberately broken path (drops one matched pair) must be caught on
    every instance, ddmin-minimized, and dumped as a replayable artifact."""
    def broken(g, base=MatcherConfig(), warm_start="cheap"):
        cm, rm = SOLVE_PATHS["jnp"].run_host(g, base=base,
                                             warm_start=warm_start)
        cm, rm = cm.copy(), rm.copy()
        c = int(np.argmax(cm >= 0))
        rm[cm[c]] = -1
        cm[c] = -1
        return cm, rm

    register_solve_path("broken", runner=broken)
    try:
        report = verify_corpus(scale="mini", paths=("broken",),
                               warm_starts=("cheap",), families=("mtx",),
                               rcp=False, artifact_dir=str(tmp_path),
                               minimize_budget=32)
    finally:
        unregister_solve_path("broken")
    assert "broken" not in SOLVE_PATHS
    (fail,) = report.failures
    assert fail.cardinality == fail.expected - 1 == 9
    with open(fail.artifact) as f:
        art = json.load(f)
    assert art["schema"] == ARTIFACT_SCHEMA
    assert art["minimized"] and art["path"] == "broken"
    assert (art["expected"], art["got"]) == (10, 9)
    # off-by-one reproduces on any matchable subgraph, so ddmin should get
    # close to a single edge well within the budget
    assert 1 <= len(art["edges"]) <= 4
    for c, r in art["edges"]:
        assert 0 <= c < art["nc"] and 0 <= r < art["nr"]


def test_minimizer_respects_budget_and_predicate():
    edges = np.stack([np.arange(16) % 4, np.arange(16) % 5], axis=1)
    calls = []

    def fails(cand):
        calls.append(len(cand))
        return any((c, r) == (3, 3) for c, r in cand.tolist())

    out = minimize_failing_edges(edges[:, 0], edges[:, 1], 4, 5, fails,
                                 max_checks=50)
    assert fails(out) and len(out) <= 2
    assert len(calls) <= 52


# ---------------------------------------------------------------------------
# tentpole: the per-family heuristic gate
# ---------------------------------------------------------------------------
def test_heuristic_model_anchors():
    g = _mini()["rand"]
    tr = trace_instance(g, warm_start="cheap")
    assert tr.levels >= 1 and tr.nnz_pad == g.nnz_pad
    rel, pulls = modelled_rel(tr, 1e-6, 1e-6)     # never pull == push-only
    assert rel == 1.0 and pulls == 0
    rel_all, pulls_all = modelled_rel(tr, 1e6, 1e6)
    # always-pull pulls every level with a live frontier (fe > 0); empty-
    # frontier closing levels still push since fe*alpha > pe can't hold
    live = sum(1 for ph in tr.phases for fe, pe, _ in ph if fe * 1e6 > pe)
    assert pulls_all == live and 0 < live <= tr.levels
    assert rel_all != 1.0
    assert (1e-6, 1e-6) in sweep_grid() and (8.0, 32.0) in sweep_grid()


def test_heuristic_gate_catches_broken_alpha():
    """Acceptance: a deliberately broken dirop_alpha/beta (always-pull) must
    fail benchmarks.run's regression gate on the corpus.heuristic rows,
    exactly like a perf regression — and the defaults must not."""
    from benchmarks import run as bench_run
    from benchmarks.corpus import heuristic_rows

    assert "corpus" in bench_run.BENCHES
    assert "corpus" in bench_run.REGRESSION_BENCHES
    assert "corpus.heuristic" in bench_run.GATED_SETS

    insts = corpus_instances("mini", families=("rand", "sparse"))
    good, traces = heuristic_rows(insts)          # shipped defaults (8, 32)
    bad, _ = heuristic_rows(insts, traces=traces, alpha=1e6, beta=1e6)
    baseline = {"benches": {"corpus": good}}
    assert len(bench_run._rel_index(baseline, "corpus")) == len(insts)

    fails = bench_run.check_regressions(
        baseline, {"benches": {"corpus": bad}}, tolerance=0.02)
    assert fails and all("corpus" in f for f in fails)
    # same thresholds: bit-identical rows, no false positive even at 0%
    assert not bench_run.check_regressions(
        baseline, {"benches": {"corpus": good}}, tolerance=0.0)
    # a vanished family row is itself a failure (no silently narrower gate)
    fewer, _ = heuristic_rows(
        {"rand": insts["rand"]}, traces={"rand": traces["rand"]})
    assert bench_run.check_regressions(
        baseline, {"benches": {"corpus": fewer}}, tolerance=0.02)


def test_corpus_bench_registered_in_harness():
    from benchmarks import run as bench_run
    assert bench_run.BENCHES["corpus"].__module__ == "benchmarks.corpus"
    # gated sets must survive the CSV round-trip used by --json artifacts
    recs = bench_run._records(["corpus.heuristic,family,set,rel",
                               "corpus.heuristic,grid,orig,0.700"])
    assert recs == [("corpus.heuristic",
                     {"family": "grid", "set": "orig", "rel": "0.700"})]
