"""Every frontier-sweep execution path against the one deterministic
min-merge contract (ISSUE 4: the fused kernel must be bit-identical to the
scatter_min-merged proposals on all variants; ISSUE 5: so must the pull
sweeps of the direction-optimizing engine), plus the edge-tile geometry
fixes and the ALTERNATE micro-optimizations.

Split by concern:
* kernel-level: fused winners == scatter_min(legacy proposals) == fused ref
  == pull winners over the CSC-permuted edges;
* CSC mirror: `DeviceCSR.with_csc` agrees with the host transpose and rides
  every shape operation (pad_to / pad_vertices / stack);
* solver-level: jnp / Pallas-interpret / Pallas-compiled / adaptive / dirop
  sweeps give bit-identical matchings across the paper's variant matrix and
  both WR encodings (compiled skipped on hosts without a non-CPU backend);
* dirop: forced-pull and forced-push runs agree; the compact pull falls
  back cleanly on skewed degrees; config plumbing (mirror errors, the
  adaptive/dirop exclusion, hysteresis bounds) fails loudly;
* geometry: `default_block_edges` no longer degenerates on prime edge
  counts, bad tiles raise a typed ValueError at trace time;
* ALTERNATE: the gather-hoisted, scatter-skipping loop is a step-count-
  preserving rewrite of the straightforward body.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MatcherConfig, VARIANTS, cheap_matching_jax,
                        maximum_cardinality, maximum_matching,
                        validate_matching)
from repro.graphs import random_bipartite, scaled_free
from repro.kernels.frontier_expand import (frontier_expand,
                                           frontier_expand_fused,
                                           frontier_expand_fused_ref,
                                           frontier_expand_pull,
                                           frontier_expand_pull_ref,
                                           resolve_interpret)
from repro.matching import DeviceCSR, Matcher, SOLVE_PATHS
from repro.matching.solve import (IINF, _alternate, default_block_edges,
                                  level0_state, scatter_min)

CPU_ONLY = jax.default_backend() == "cpu"


def _bfs_state(g):
    """Level-L0 probe state via the solver's own ``level0_state`` init."""
    cm, rm = cheap_matching_jax(g)
    cmj = jnp.concatenate([jnp.asarray(cm), jnp.array([-3], jnp.int32)])
    rmj = jnp.concatenate([jnp.asarray(rm), jnp.array([-3], jnp.int32)])
    bfs, root = level0_state(cmj)
    return bfs, root, rmj


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nc,nr,deg,pad,blk", [
    (256, 256, 3.0, 1024, 256),
    (500, 700, 4.0, 3000, 512),      # pad not a multiple of the tile
    (300, 200, 5.0, 2048, 999),      # tile not a divisor of anything nice
    (64, 64, 2.0, 128, 4096),        # tile bigger than the edge array
])
def test_fused_kernel_bit_identical_to_scatter_min(nc, nr, deg, pad, blk):
    g = random_bipartite(nc, nr, deg, seed=nc + nr, pad_to=pad)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    for rt in (root, None):
        prop = frontier_expand(ecol, cadj, bfs, rt, rmj, 2, block_edges=blk)
        merged = scatter_min(nr, jnp.where(prop < IINF, cadj, nr), prop)
        fused = frontier_expand_fused(ecol, cadj, bfs, rt, rmj, 2,
                                      block_edges=blk)
        ref = frontier_expand_fused_ref(ecol, cadj, bfs, rt, rmj,
                                        jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(merged))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))


@pytest.mark.parametrize("nc,nr,deg,pad,blk", [
    (256, 256, 3.0, 1024, 256),
    (500, 700, 4.0, 3000, 512),      # pad not a multiple of the tile
    (300, 200, 5.0, 2048, 999),      # tile not a divisor of anything nice
])
def test_pull_kernel_bit_identical_to_push_winners(nc, nr, deg, pad, blk):
    """The pull kernel streams the CSC-permuted edges; min is the merge, so
    its winners must equal the fused/push winners bit for bit."""
    g = random_bipartite(nc, nr, deg, seed=nc + 3 * nr, pad_to=pad)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    d = DeviceCSR.from_host(g).with_csc()
    for rt in (root, None):
        push = frontier_expand_fused(ecol, cadj, bfs, rt, rmj, 2,
                                     block_edges=blk)
        pull = frontier_expand_pull(d.radj, d.erow, bfs, rt, rmj, 2,
                                    block_edges=blk)
        ref = frontier_expand_pull_ref(d.radj, d.erow, bfs, rt, rmj,
                                       jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(pull), np.asarray(push))
        np.testing.assert_array_equal(np.asarray(pull), np.asarray(ref))


# ---------------------------------------------------------------------------
# the CSC mirror
# ---------------------------------------------------------------------------
def test_csc_mirror_matches_host_transpose_and_threads_through_ops():
    g = random_bipartite(60, 50, 3.0, seed=5)
    t = g.transpose()
    d = DeviceCSR.from_host(g).with_csc()
    np.testing.assert_array_equal(np.asarray(d.rxadj), t.cxadj)
    np.testing.assert_array_equal(np.asarray(d.radj)[: g.nnz],
                                  t.cadj[: t.nnz])
    np.testing.assert_array_equal(np.asarray(d.erow)[: g.nnz],
                                  t.ecol[: t.nnz])
    # eperm is a true permutation mapping row-sorted slots to CSR slots
    perm = np.asarray(d.eperm)
    assert sorted(perm.tolist()) == list(range(d.nnz_pad))
    np.testing.assert_array_equal(np.asarray(d.ecol)[perm],
                                  np.asarray(d.radj))
    np.testing.assert_array_equal(np.asarray(d.cadj)[perm],
                                  np.asarray(d.erow))
    assert d.has_csc and d.bucket_key == (60, 50, d.nnz_pad, "csc")
    assert not d.drop_csc().has_csc

    # pad_to: mirror sentinels extend, eperm stays a permutation
    d2 = d.pad_to(2 * d.nnz_pad)
    perm2 = np.asarray(d2.eperm)
    assert sorted(perm2.tolist()) == list(range(d2.nnz_pad))
    np.testing.assert_array_equal(np.asarray(d2.ecol)[perm2],
                                  np.asarray(d2.radj))

    # pad_vertices: new rows are edgeless, sentinels re-encoded
    d3 = d.pad_vertices(64, 64)
    assert d3.rxadj.shape == (65,) and int(d3.rxadj[-1]) == g.nnz
    assert (np.asarray(d3.erow)[g.nnz:] == 64).all()
    np.testing.assert_array_equal(np.asarray(d3.radj)[: g.nnz],
                                  t.cadj[: t.nnz])

    # stack: mirror leaves gain the batch axis; mixing is refused
    b = DeviceCSR.stack([d, d])
    assert b.bucket_key == (2, 60, 50, d.nnz_pad, "csc")
    np.testing.assert_array_equal(np.asarray(b.unstack()[1].radj),
                                  np.asarray(d.radj))
    with pytest.raises(AssertionError, match="with_csc"):
        DeviceCSR.stack([d, d.drop_csc()])


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------
def test_default_block_edges_never_degenerate():
    """The old gcd collapsed to 1-lane tiles on prime edge counts; the tile
    is now clamped-desired with a 128-lane floor (padding absorbs the rest).
    """
    for nnz in (997, 1, 130, 2048, 4096, 65536, 99991):
        for schedule in ("ct", "mt"):
            blk = default_block_edges(nnz, schedule)
            assert blk >= 128, (nnz, schedule, blk)
            assert blk % 128 == 0, (nnz, schedule, blk)
    assert default_block_edges(65536, "ct") == 4096    # CT coarse tiles
    assert default_block_edges(65536, "mt") == 512     # MT fine tiles
    assert default_block_edges(997, "ct") == 1024      # clamped to the pad
    assert default_block_edges(64, "mt") == 128        # floor


def test_bad_block_edges_raises_typed_error():
    g = random_bipartite(64, 64, 2.0, seed=0, pad_to=256)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    for entry in (frontier_expand, frontier_expand_fused,
                  frontier_expand_pull):
        with pytest.raises(ValueError, match=r"block_edges=0 for nnz=256"):
            entry(ecol, cadj, bfs, root, rmj, 2, block_edges=0)
        with pytest.raises(ValueError, match="block_edges"):
            entry(ecol, cadj, bfs, root, rmj, 2, block_edges=-4)


# ---------------------------------------------------------------------------
# solver level: the full variant matrix, every sweep path
# ---------------------------------------------------------------------------
def _encoding_matrix():
    """All eight variants, and for the WR kernel both endpoint encodings."""
    out = {}
    for v in VARIANTS:
        encs = (False, True) if v.kernel == "gpubfs_wr" else (False,)
        for e in encs:
            cfg = dataclasses.replace(v, wr_exact=e)
            out[cfg.name + ("-exact" if e and not v.wr_exact else "")] = cfg
    return sorted(out.values(), key=lambda c: (c.name, c.wr_exact))


# the registered solve paths ARE the sweep-path list: anything added to
# repro.matching.SOLVE_PATHS is automatically held to the bit-identical
# contract here (jnp is the reference; sharded re-dispatches these configs)
PATHS = {name: dict(p.overrides)
         for name, p in SOLVE_PATHS.items()
         if not p.sharded and p.runner is None and name != "jnp"}


def test_registry_covers_every_single_device_path():
    assert set(PATHS) == {"legacy", "fused", "adaptive", "dirop",
                          "dirop_pallas"}


@pytest.mark.parametrize("cfg", _encoding_matrix(), ids=lambda c:
                         f"{c.name}{'-exact' if c.wr_exact else ''}")
def test_sweep_paths_bit_identical(cfg):
    g = random_bipartite(180, 170, 3.0, seed=17)
    opt = maximum_cardinality(g)
    cm0, rm0 = cheap_matching_jax(g)
    ref_cm, ref_rm, st = maximum_matching(g, cfg, cm0, rm0)
    assert validate_matching(g, ref_cm, ref_rm) == opt, st
    for pname, overrides in PATHS.items():
        pcfg = dataclasses.replace(cfg, **overrides)
        cm, rm, pst = maximum_matching(g, pcfg, cm0, rm0)
        np.testing.assert_array_equal(ref_cm, cm, err_msg=pname)
        np.testing.assert_array_equal(ref_rm, rm, err_msg=pname)


@pytest.mark.skipif(CPU_ONLY, reason="no non-CPU backend: Pallas cannot "
                    "compile, interpret parity is covered above")
@pytest.mark.parametrize("cfg", [VARIANTS[1], VARIANTS[3]],
                         ids=lambda c: c.name)
def test_sweep_paths_compiled_parity(cfg):
    """On accelerator hosts the compiled kernels must equal the jnp path."""
    g = random_bipartite(256, 256, 3.0, seed=23)
    cm0, rm0 = cheap_matching_jax(g)
    ref_cm, ref_rm, _ = maximum_matching(g, cfg, cm0, rm0)
    for fused in (True, False):
        pcfg = dataclasses.replace(cfg, use_pallas=True, pallas_fused=fused,
                                   pallas_interpret=False)
        cm, rm, _ = maximum_matching(g, pcfg, cm0, rm0)
        np.testing.assert_array_equal(ref_cm, cm)
        np.testing.assert_array_equal(ref_rm, rm)
    # the compiled pull kernel (direction-optimizing path)
    dcfg = dataclasses.replace(cfg, use_pallas=True, dirop=True,
                               pallas_interpret=False)
    cm, rm, _ = maximum_matching(g, dcfg, cm0, rm0)
    np.testing.assert_array_equal(ref_cm, cm)
    np.testing.assert_array_equal(ref_rm, rm)


def test_adaptive_runtime_fallback_on_skewed_degrees():
    """Power-law columns exceed dmax -> runtime falls back to the dense
    sweep; the result must stay bit-identical and maximum."""
    g = scaled_free(300, 300, 5.0, seed=3)
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    ref_cm, ref_rm, _ = maximum_matching(g, cfg)
    acfg = dataclasses.replace(cfg, adaptive_frontier=True,
                               compact_cap=64, compact_dmax=2)
    cm, rm, _ = maximum_matching(g, acfg)
    np.testing.assert_array_equal(ref_cm, cm)
    np.testing.assert_array_equal(ref_rm, rm)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


# ---------------------------------------------------------------------------
# the direction-optimizing engine
# ---------------------------------------------------------------------------
def test_dirop_forced_directions_agree():
    """Pin the heuristic to each extreme: always-pull-if-possible vs
    never-pull must still produce the reference matching bit for bit (the
    direction decision is a pure performance choice)."""
    g = random_bipartite(220, 200, 3.5, seed=29)
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    ref_cm, ref_rm, _ = maximum_matching(g, cfg)
    for alpha, beta in ((1e6, 1e6), (1e-6, 1e-6)):
        dcfg = dataclasses.replace(cfg, dirop=True, dirop_alpha=alpha,
                                   dirop_beta=beta)
        cm, rm, _ = maximum_matching(g, dcfg)
        np.testing.assert_array_equal(ref_cm, cm, err_msg=str(alpha))
        np.testing.assert_array_equal(ref_rm, rm, err_msg=str(alpha))


def test_dirop_compact_pull_fallback_on_skewed_degrees():
    """Power-law rows exceed pull_dmax -> the compact pull is ineligible
    and the engine stays on the push sweep; results stay bit-identical."""
    g = scaled_free(300, 300, 5.0, seed=7).permuted(2)
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    ref_cm, ref_rm, _ = maximum_matching(g, cfg)
    dcfg = dataclasses.replace(cfg, dirop=True, pull_cap=64, pull_dmax=2)
    cm, rm, _ = maximum_matching(g, dcfg)
    np.testing.assert_array_equal(ref_cm, cm)
    np.testing.assert_array_equal(ref_rm, rm)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_dirop_requires_the_csc_mirror():
    g = random_bipartite(64, 64, 2.0, seed=1)
    m = Matcher(MatcherConfig(dirop=True))
    with pytest.raises(ValueError, match="with_csc"):
        m.run(DeviceCSR.from_host(g))
    st = m.run(DeviceCSR.from_host(g).with_csc())
    assert int(st.cardinality) == maximum_cardinality(g)


def test_dirop_config_validation():
    with pytest.raises(ValueError, match="generalizes"):
        MatcherConfig(dirop=True, adaptive_frontier=True)
    with pytest.raises(AssertionError, match="hysteresis"):
        MatcherConfig(dirop_alpha=8.0, dirop_beta=4.0)  # beta < alpha
    # the dirop knobs are dataclass fields -> part of every cache key
    a = MatcherConfig(dirop=True)
    b = MatcherConfig(dirop=True, dirop_alpha=2.0, dirop_beta=2.0)
    assert a != b and hash(a) != hash(b)


# ---------------------------------------------------------------------------
# config / cache plumbing
# ---------------------------------------------------------------------------
def test_interpret_resolution_in_cache_key():
    from repro.matching import Matcher
    auto = Matcher(MatcherConfig(use_pallas=True))
    assert auto.config.pallas_interpret == (jax.default_backend() == "cpu")
    assert resolve_interpret(None) == auto.config.pallas_interpret
    pinned = Matcher(MatcherConfig(use_pallas=True, pallas_interpret=True))
    assert pinned.config.pallas_interpret is True
    # the resolved bool (not the None marker) is what lands in cache keys
    assert auto.config == MatcherConfig(
        use_pallas=True, pallas_interpret=auto.config.pallas_interpret)


# ---------------------------------------------------------------------------
# ALTERNATE: optimized loop == straightforward loop, step for step
# ---------------------------------------------------------------------------
def _alternate_reference(cmatch, rmatch, pred, start_mask, max_steps):
    """The pre-optimization ALTERNATE body (two pred gathers per step, both
    scatters unconditional) with the step count exposed."""
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1
    rows = jnp.arange(nr + 1, dtype=jnp.int32)
    cur0 = jnp.where(start_mask, rows, jnp.int32(-1))

    def cond(carry):
        cur, _, _, steps = carry
        return jnp.any(cur >= 0) & (steps < max_steps)

    def body(carry):
        cur, cmatch, rmatch, steps = carry
        active = cur >= 0
        curc = jnp.clip(cur, 0, nr)
        mc = pred[curc]
        mcc = jnp.clip(mc, 0, nc)
        mr = cmatch[mcc]
        brk = active & (mr >= 0) & (pred[jnp.clip(mr, 0, nr)] == mc)
        act = active & ~brk
        cprop = scatter_min(nc, jnp.where(act, mcc, nc),
                            jnp.where(act, cur, IINF))
        cmatch = jnp.where(cprop < IINF, cprop, cmatch)
        rprop = scatter_min(nr, jnp.where(act, curc, nr),
                            jnp.where(act, mc, IINF))
        rmatch = jnp.where(rprop < IINF, rprop, rmatch)
        cur = jnp.where(act, mr, jnp.int32(-1))
        return cur, cmatch, rmatch, steps + 1

    _, cmatch, rmatch, steps = jax.lax.while_loop(
        cond, body, (cur0, cmatch, rmatch, jnp.int32(0)))
    return cmatch, rmatch, steps


@pytest.mark.parametrize("seed", range(6))
def test_alternate_optimized_is_step_count_preserving(seed):
    rng = np.random.default_rng(seed)
    nc = nr = 60
    pred = jnp.asarray(rng.integers(0, nc + 1, size=nr + 1), jnp.int32)
    cmatch = jnp.asarray(rng.integers(-1, nr, size=nc + 1), jnp.int32)
    rmatch = jnp.asarray(rng.integers(-2, nc, size=nr + 1), jnp.int32)
    start = jnp.asarray(rng.random(nr + 1) < 0.2)
    start = start.at[nr].set(False)
    max_steps = jnp.int32(12)
    ref = _alternate_reference(cmatch, rmatch, pred, start, max_steps)
    opt = _alternate(cmatch, rmatch, pred, start, max_steps)
    for a, b, what in zip(ref, opt, ("cmatch", "rmatch", "steps")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)
