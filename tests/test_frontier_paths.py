"""Every frontier-sweep execution path against the one deterministic
min-merge contract (ISSUE 4: the fused kernel must be bit-identical to the
scatter_min-merged proposals on all variants), plus the edge-tile geometry
fixes and the ALTERNATE micro-optimizations.

Split by concern:
* kernel-level: fused winners == scatter_min(legacy proposals) == fused ref;
* solver-level: jnp / Pallas-interpret / Pallas-compiled / adaptive sweeps
  give bit-identical matchings across the paper's variant matrix and both
  WR encodings (compiled skipped on hosts without a non-CPU backend);
* geometry: `default_block_edges` no longer degenerates on prime edge
  counts, bad tiles raise a typed ValueError at trace time;
* ALTERNATE: the gather-hoisted, scatter-skipping loop is a step-count-
  preserving rewrite of the straightforward body.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MatcherConfig, VARIANTS, cheap_matching_jax,
                        maximum_cardinality, maximum_matching,
                        validate_matching)
from repro.graphs import random_bipartite, scaled_free
from repro.kernels.frontier_expand import (frontier_expand,
                                           frontier_expand_fused,
                                           frontier_expand_fused_ref,
                                           resolve_interpret)
from repro.matching.solve import (IINF, _alternate, default_block_edges,
                                  level0_state, scatter_min)

CPU_ONLY = jax.default_backend() == "cpu"


def _bfs_state(g):
    """Level-L0 probe state via the solver's own ``level0_state`` init."""
    cm, rm = cheap_matching_jax(g)
    cmj = jnp.concatenate([jnp.asarray(cm), jnp.array([-3], jnp.int32)])
    rmj = jnp.concatenate([jnp.asarray(rm), jnp.array([-3], jnp.int32)])
    bfs, root = level0_state(cmj)
    return bfs, root, rmj


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nc,nr,deg,pad,blk", [
    (256, 256, 3.0, 1024, 256),
    (500, 700, 4.0, 3000, 512),      # pad not a multiple of the tile
    (300, 200, 5.0, 2048, 999),      # tile not a divisor of anything nice
    (64, 64, 2.0, 128, 4096),        # tile bigger than the edge array
])
def test_fused_kernel_bit_identical_to_scatter_min(nc, nr, deg, pad, blk):
    g = random_bipartite(nc, nr, deg, seed=nc + nr, pad_to=pad)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    for rt in (root, None):
        prop = frontier_expand(ecol, cadj, bfs, rt, rmj, 2, block_edges=blk)
        merged = scatter_min(nr, jnp.where(prop < IINF, cadj, nr), prop)
        fused = frontier_expand_fused(ecol, cadj, bfs, rt, rmj, 2,
                                      block_edges=blk)
        ref = frontier_expand_fused_ref(ecol, cadj, bfs, rt, rmj,
                                        jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(merged))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------
def test_default_block_edges_never_degenerate():
    """The old gcd collapsed to 1-lane tiles on prime edge counts; the tile
    is now clamped-desired with a 128-lane floor (padding absorbs the rest).
    """
    for nnz in (997, 1, 130, 2048, 4096, 65536, 99991):
        for schedule in ("ct", "mt"):
            blk = default_block_edges(nnz, schedule)
            assert blk >= 128, (nnz, schedule, blk)
            assert blk % 128 == 0, (nnz, schedule, blk)
    assert default_block_edges(65536, "ct") == 4096    # CT coarse tiles
    assert default_block_edges(65536, "mt") == 512     # MT fine tiles
    assert default_block_edges(997, "ct") == 1024      # clamped to the pad
    assert default_block_edges(64, "mt") == 128        # floor


def test_bad_block_edges_raises_typed_error():
    g = random_bipartite(64, 64, 2.0, seed=0, pad_to=256)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    for entry in (frontier_expand, frontier_expand_fused):
        with pytest.raises(ValueError, match=r"block_edges=0 for nnz=256"):
            entry(ecol, cadj, bfs, root, rmj, 2, block_edges=0)
        with pytest.raises(ValueError, match="block_edges"):
            entry(ecol, cadj, bfs, root, rmj, 2, block_edges=-4)


# ---------------------------------------------------------------------------
# solver level: the full variant matrix, every sweep path
# ---------------------------------------------------------------------------
def _encoding_matrix():
    """All eight variants, and for the WR kernel both endpoint encodings."""
    out = {}
    for v in VARIANTS:
        encs = (False, True) if v.kernel == "gpubfs_wr" else (False,)
        for e in encs:
            cfg = dataclasses.replace(v, wr_exact=e)
            out[cfg.name + ("-exact" if e and not v.wr_exact else "")] = cfg
    return sorted(out.values(), key=lambda c: (c.name, c.wr_exact))


PATHS = {
    "pallas_fused": dict(use_pallas=True),
    "pallas_legacy": dict(use_pallas=True, pallas_fused=False),
    "adaptive": dict(adaptive_frontier=True, compact_cap=64, compact_dmax=8),
}


@pytest.mark.parametrize("cfg", _encoding_matrix(), ids=lambda c:
                         f"{c.name}{'-exact' if c.wr_exact else ''}")
def test_sweep_paths_bit_identical(cfg):
    g = random_bipartite(180, 170, 3.0, seed=17)
    opt = maximum_cardinality(g)
    cm0, rm0 = cheap_matching_jax(g)
    ref_cm, ref_rm, st = maximum_matching(g, cfg, cm0, rm0)
    assert validate_matching(g, ref_cm, ref_rm) == opt, st
    for pname, overrides in PATHS.items():
        pcfg = dataclasses.replace(cfg, **overrides)
        cm, rm, pst = maximum_matching(g, pcfg, cm0, rm0)
        np.testing.assert_array_equal(ref_cm, cm, err_msg=pname)
        np.testing.assert_array_equal(ref_rm, rm, err_msg=pname)


@pytest.mark.skipif(CPU_ONLY, reason="no non-CPU backend: Pallas cannot "
                    "compile, interpret parity is covered above")
@pytest.mark.parametrize("cfg", [VARIANTS[1], VARIANTS[3]],
                         ids=lambda c: c.name)
def test_sweep_paths_compiled_parity(cfg):
    """On accelerator hosts the compiled kernels must equal the jnp path."""
    g = random_bipartite(256, 256, 3.0, seed=23)
    cm0, rm0 = cheap_matching_jax(g)
    ref_cm, ref_rm, _ = maximum_matching(g, cfg, cm0, rm0)
    for fused in (True, False):
        pcfg = dataclasses.replace(cfg, use_pallas=True, pallas_fused=fused,
                                   pallas_interpret=False)
        cm, rm, _ = maximum_matching(g, pcfg, cm0, rm0)
        np.testing.assert_array_equal(ref_cm, cm)
        np.testing.assert_array_equal(ref_rm, rm)


def test_adaptive_runtime_fallback_on_skewed_degrees():
    """Power-law columns exceed dmax -> runtime falls back to the dense
    sweep; the result must stay bit-identical and maximum."""
    g = scaled_free(300, 300, 5.0, seed=3)
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    ref_cm, ref_rm, _ = maximum_matching(g, cfg)
    acfg = dataclasses.replace(cfg, adaptive_frontier=True,
                               compact_cap=64, compact_dmax=2)
    cm, rm, _ = maximum_matching(g, acfg)
    np.testing.assert_array_equal(ref_cm, cm)
    np.testing.assert_array_equal(ref_rm, rm)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


# ---------------------------------------------------------------------------
# config / cache plumbing
# ---------------------------------------------------------------------------
def test_interpret_resolution_in_cache_key():
    from repro.matching import Matcher
    auto = Matcher(MatcherConfig(use_pallas=True))
    assert auto.config.pallas_interpret == (jax.default_backend() == "cpu")
    assert resolve_interpret(None) == auto.config.pallas_interpret
    pinned = Matcher(MatcherConfig(use_pallas=True, pallas_interpret=True))
    assert pinned.config.pallas_interpret is True
    # the resolved bool (not the None marker) is what lands in cache keys
    assert auto.config == MatcherConfig(
        use_pallas=True, pallas_interpret=auto.config.pallas_interpret)


# ---------------------------------------------------------------------------
# ALTERNATE: optimized loop == straightforward loop, step for step
# ---------------------------------------------------------------------------
def _alternate_reference(cmatch, rmatch, pred, start_mask, max_steps):
    """The pre-optimization ALTERNATE body (two pred gathers per step, both
    scatters unconditional) with the step count exposed."""
    nc = cmatch.shape[0] - 1
    nr = rmatch.shape[0] - 1
    rows = jnp.arange(nr + 1, dtype=jnp.int32)
    cur0 = jnp.where(start_mask, rows, jnp.int32(-1))

    def cond(carry):
        cur, _, _, steps = carry
        return jnp.any(cur >= 0) & (steps < max_steps)

    def body(carry):
        cur, cmatch, rmatch, steps = carry
        active = cur >= 0
        curc = jnp.clip(cur, 0, nr)
        mc = pred[curc]
        mcc = jnp.clip(mc, 0, nc)
        mr = cmatch[mcc]
        brk = active & (mr >= 0) & (pred[jnp.clip(mr, 0, nr)] == mc)
        act = active & ~brk
        cprop = scatter_min(nc, jnp.where(act, mcc, nc),
                            jnp.where(act, cur, IINF))
        cmatch = jnp.where(cprop < IINF, cprop, cmatch)
        rprop = scatter_min(nr, jnp.where(act, curc, nr),
                            jnp.where(act, mc, IINF))
        rmatch = jnp.where(rprop < IINF, rprop, rmatch)
        cur = jnp.where(act, mr, jnp.int32(-1))
        return cur, cmatch, rmatch, steps + 1

    _, cmatch, rmatch, steps = jax.lax.while_loop(
        cond, body, (cur0, cmatch, rmatch, jnp.int32(0)))
    return cmatch, rmatch, steps


@pytest.mark.parametrize("seed", range(6))
def test_alternate_optimized_is_step_count_preserving(seed):
    rng = np.random.default_rng(seed)
    nc = nr = 60
    pred = jnp.asarray(rng.integers(0, nc + 1, size=nr + 1), jnp.int32)
    cmatch = jnp.asarray(rng.integers(-1, nr, size=nc + 1), jnp.int32)
    rmatch = jnp.asarray(rng.integers(-2, nc, size=nr + 1), jnp.int32)
    start = jnp.asarray(rng.random(nr + 1) < 0.2)
    start = start.at[nr].set(False)
    max_steps = jnp.int32(12)
    ref = _alternate_reference(cmatch, rmatch, pred, start, max_steps)
    opt = _alternate(cmatch, rmatch, pred, start, max_steps)
    for a, b, what in zip(ref, opt, ("cmatch", "rmatch", "steps")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)
