"""repro.serving: bucketizer admission, scheduler policy, AOT warmup, and
service-level cardinality parity with the direct Matcher."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.core import validate_matching
from repro.graphs import (banded, comb_chain, community_graph, grid_graph,
                          kron_graph, mtx_fixture, random_bipartite,
                          scaled_free)
from repro.matching import (DeviceCSR, Matcher, MatcherConfig,
                            compile_cache_clear, compile_cache_info)
from repro.matching.cache import get_compiled, set_max_entries
from repro.serving import (Bucketizer, MatchingService, MicroBatcher,
                           OversizeGraphError, SizeBucket, batch_bucket,
                           batch_ladder, synthetic_bucket_graph)

CFG = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
BUCKET = SizeBucket(256, 256, 2048)


def families():
    """One instance of every corpus generator family standing in for the
    paper's UFL classes, all sized to share one declared bucket."""
    return {
        "random": random_bipartite(200, 180, 3.0, seed=1),
        "kron": kron_graph(7, 6, seed=2),
        "grid": grid_graph(12),
        "free": scaled_free(150, 160, 4.0, seed=3),
        "band": banded(200, band=3, density=0.5, seed=5),
        "community": community_graph(192, 192, blocks=6, avg_deg=3.0, seed=6),
        "comb": comb_chain(96, teeth=16, seed=7),
        "mtx": mtx_fixture(),
    }


def direct_cardinality(g):
    return int(Matcher(CFG, warm_start="cheap").run(
        DeviceCSR.from_host(g).bucketed()).cardinality)


# ---------------------------------------------------------------------------
# Bucketizer: placement, padding, typed rejection
# ---------------------------------------------------------------------------
def test_bucketizer_pads_onto_declared_bucket():
    g = random_bipartite(200, 180, 3.0, seed=1)
    adm = Bucketizer((BUCKET,)).admit(g)
    assert adm.route == "bucket" and adm.bucket == BUCKET
    assert (adm.graph.nc, adm.graph.nr) == (256, 256)
    assert adm.graph.nnz_pad == 2048
    assert (adm.nc, adm.nr, adm.nnz) == (200, 180, g.nnz)
    assert adm.pad_edges == 2048 - g.nnz
    assert adm.pad_vertex_slots == (256 - 200) + (256 - 180)
    # padding vertices are isolated: the maximum matching is unchanged
    st = Matcher(CFG, warm_start="cheap").run(adm.graph)
    assert int(st.cardinality) == direct_cardinality(g)


def test_bucketizer_accepts_device_graph():
    g = random_bipartite(100, 90, 3.0, seed=4)
    adm = Bucketizer((BUCKET,)).admit(DeviceCSR.from_host(g))
    assert adm.bucket == BUCKET and adm.graph.nnz_pad == 2048
    st = Matcher(CFG, warm_start="cheap").run(adm.graph)
    assert int(st.cardinality) == direct_cardinality(g)


def test_bucketizer_oversize_typed_rejection():
    big = random_bipartite(400, 400, 3.0, seed=5)
    with pytest.raises(OversizeGraphError) as ei:
        Bucketizer((BUCKET,)).admit(big)
    assert (ei.value.nc, ei.value.nr) == (400, 400)
    assert ei.value.largest == BUCKET


def test_bucketizer_picks_smallest_fitting_bucket():
    small, large = SizeBucket(128, 128, 1024), SizeBucket(512, 512, 4096)
    bz = Bucketizer((large, small))          # order must not matter
    assert bz.admit(random_bipartite(100, 100, 3.0, seed=6)).bucket == small
    assert bz.admit(random_bipartite(300, 300, 3.0, seed=6)).bucket == large


# ---------------------------------------------------------------------------
# Scheduler: full/deadline/drain policy, AIMD target, batch ladder
# ---------------------------------------------------------------------------
def test_batch_ladder_and_bucket():
    assert batch_ladder(8) == (1, 2, 4, 8)
    assert batch_ladder(6) == (1, 2, 4, 6)
    assert batch_ladder(1) == (1,)
    assert batch_bucket(3, 8) == 4
    assert batch_bucket(5, 6) == 6
    assert batch_bucket(1, 8) == 1
    assert batch_bucket(8, 8) == 8


def test_scheduler_fixed_target_flushes_on_full():
    mb = MicroBatcher(max_batch=4, max_delay_s=1.0, adaptive=False)
    for i in range(3):
        assert mb.add("k", i, now=0.0) is None
    flush = mb.add("k", 3, now=0.0)
    assert flush is not None and flush.reason == "full"
    assert len(flush.items) == 4 and mb.pending == 0


def test_scheduler_deadline_flush_with_fake_clock():
    mb = MicroBatcher(max_batch=4, max_delay_s=0.5, adaptive=False)
    mb.add("k", "a", now=10.0)
    assert mb.due(now=10.4) == []
    assert mb.next_deadline() == 10.5
    (flush,) = mb.due(now=10.5)
    assert flush.reason == "deadline" and len(flush.items) == 1
    assert mb.next_deadline() is None


def test_scheduler_adaptive_target():
    mb = MicroBatcher(max_batch=8, max_delay_s=0.5, adaptive=True)
    assert mb.target("k") == 1
    assert mb.add("k", 0, now=0.0).reason == "full"   # target 1 -> immediate
    assert mb.target("k") == 2                        # doubled
    assert mb.add("k", 1, now=0.0) is None
    assert mb.add("k", 2, now=0.0).reason == "full"
    assert mb.target("k") == 4
    # a deadline flush drops the target straight to the observed size, so
    # sparse traffic goes back to immediate singleton dispatch
    mb.add("k", 3, now=1.0)
    (flush,) = mb.due(now=2.0)
    assert flush.reason == "deadline"
    assert mb.target("k") == 1
    assert mb.add("k", 4, now=3.0).reason == "full"   # no deadline wait


def test_scheduler_drain_flushes_every_key():
    mb = MicroBatcher(max_batch=8, max_delay_s=9.0, adaptive=False)
    mb.add("a", 1, now=0.0)
    mb.add("b", 2, now=0.0)
    flushes = mb.drain()
    assert {f.key for f in flushes} == {"a", "b"}
    assert all(f.reason == "drain" for f in flushes)
    assert mb.pending == 0


# ---------------------------------------------------------------------------
# Service: parity, deadline flush, warmup, oversize routing
# ---------------------------------------------------------------------------
def test_service_parity_across_generator_families():
    fams = families()
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4,
                         max_delay_ms=20.0) as svc:
        svc.warm_up()
        futs = {name: svc.submit(g) for name, g in fams.items()}
        for name, g in fams.items():
            res = futs[name].result(timeout=300)
            assert res.route == "bucket"
            assert res.cardinality == direct_cardinality(g), name
            cm, rm = res.matching()
            assert cm.shape == (g.nc,) and rm.shape == (g.nr,)
            assert validate_matching(g, cm, rm) == res.cardinality
        snap = svc.metrics.snapshot()
    assert snap["completed"] == len(fams)
    assert 1 <= snap["dispatches"] <= len(fams)


@pytest.mark.parametrize("family", sorted(families()))
def test_service_submit_matches_direct_matcher(family):
    """Per-corpus-family: one submit() through the full admission/batching
    path returns exactly the direct Matcher's cardinality and a valid
    matching on the ORIGINAL (unpadded) vertex ranges."""
    g = families()[family]
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=2,
                         max_delay_ms=5.0) as svc:
        res = svc.submit(g).result(timeout=300)
    assert res.cardinality == direct_cardinality(g)
    cm, rm = res.matching()
    assert validate_matching(g, cm, rm) == res.cardinality


def test_service_deadline_flush_resolves_single_request():
    g = random_bipartite(128, 128, 3.0, seed=9)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=8, max_delay_ms=30.0,
                         adaptive=False) as svc:
        res = svc.submit(g).result(timeout=300)
        assert res.cardinality == direct_cardinality(g)
        snap = svc.metrics.snapshot()
    # one request against max_batch=8 (fixed target) can only flush via the
    # deadline path
    assert snap["flushes_deadline"] == 1 and snap["flushes_full"] == 0
    assert snap["dispatches"] == 1
    assert res.queue_wait_s >= 0.02                   # waited for the deadline


def test_warmup_makes_first_dispatch_compile_free():
    compile_cache_clear()
    g = random_bipartite(200, 180, 3.0, seed=1)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4,
                         max_delay_ms=5.0) as svc:
        report = svc.warm_up()
        assert report.cells == len(batch_ladder(4))   # 1 bucket x 1 cfg x 1 ws
        assert report.compiled == report.cells        # cold cache: all built
        misses0 = compile_cache_info()["misses"]
        res = svc.submit(g).result(timeout=300)
        svc.drain()
        snap = svc.metrics.snapshot()
    assert res.cardinality > 0
    # acceptance: a warmed bucket's first dispatch is a pure cache hit
    assert compile_cache_info()["misses"] == misses0
    assert snap["compile_misses"] == 0 and snap["compile_hits"] >= 1
    # warming again is a no-op
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4) as svc2:
        report2 = svc2.warm_up()
    assert report2.compiled == 0 and report2.already == report2.cells


@pytest.mark.parametrize("kernel_cfg", [
    dataclasses.replace(CFG, use_pallas=True),
    dataclasses.replace(CFG, dirop=True),
    dataclasses.replace(CFG, dirop=True, use_pallas=True),
], ids=["pallas_fused", "dirop", "dirop_pallas"])
def test_warmup_zero_miss_across_kernel_paths(kernel_cfg):
    """Serving x kernel paths: a service running the Pallas-fused or
    direction-optimizing configs still gets a compile-free first dispatch
    after warmup — the warmup grid must cover the new config axes,
    including the CSC-mirrored graph shape dirop admissions carry."""
    compile_cache_clear()
    g = random_bipartite(200, 180, 3.0, seed=1)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=kernel_cfg,
                         warm_start="cheap", max_batch=4,
                         max_delay_ms=5.0) as svc:
        report = svc.warm_up()
        assert report.compiled == report.cells      # cold cache: all built
        misses0 = compile_cache_info()["misses"]
        res = svc.submit(g).result(timeout=300)
        svc.drain()
        snap = svc.metrics.snapshot()
    # the zero-miss checks first: direct_cardinality below compiles its own
    # (non-serving) program and must not be counted against the dispatch
    assert compile_cache_info()["misses"] == misses0
    assert snap["compile_misses"] == 0 and snap["compile_hits"] >= 1
    assert res.cardinality == direct_cardinality(g)


def test_service_rejects_adaptive_frontier_synchronously():
    """run_many can never serve adaptive_frontier; the service must say so
    in the caller's thread, not via an async failure on the flush thread."""
    g = random_bipartite(128, 128, 3.0, seed=21)
    acfg = dataclasses.replace(CFG, adaptive_frontier=True)
    with pytest.raises(ValueError, match="dirop"):
        MatchingService(bucketizer=Bucketizer((BUCKET,)), config=acfg)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4,
                         max_delay_ms=5.0) as svc:
        with pytest.raises(ValueError, match="dirop"):
            svc.submit(g, config=acfg)
        res = svc.submit(g).result(timeout=300)      # service still serves
        assert res.cardinality == direct_cardinality(g)


def test_dirop_admission_attaches_csc_mirror():
    """A dirop request's admitted graph must carry the mirror (and only
    then), so the dispatched pytree matches the warmed one."""
    bz = Bucketizer((BUCKET,))
    g = random_bipartite(200, 180, 3.0, seed=1)
    assert not bz.admit(g).graph.has_csc
    assert bz.admit(g, csc=True).graph.has_csc
    mirrored = Bucketizer((BUCKET,), build_csc=True).admit(g).graph
    assert mirrored.has_csc and mirrored.bucket_key == BUCKET.key + ("csc",)


def test_service_routes_oversize_to_sharded_matcher():
    import jax
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    big = random_bipartite(320, 320, 3.0, seed=11)
    with MatchingService(
            bucketizer=Bucketizer((BUCKET,), oversize="shard"),
            config=CFG, warm_start="cheap", mesh=mesh) as svc:
        res = svc.submit(big).result(timeout=300)
        snap = svc.metrics.snapshot()
    assert res.route == "sharded" and res.bucket is None
    assert res.cardinality == direct_cardinality(big)
    cm, rm = res.matching()
    assert validate_matching(big, cm, rm) == res.cardinality
    assert snap["sharded"] == 1


def test_service_rejects_oversize_without_mesh():
    big = random_bipartite(320, 320, 3.0, seed=11)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap") as svc:
        with pytest.raises(OversizeGraphError):
            svc.submit(big)
        snap = svc.metrics.snapshot()
    assert snap["rejected"] == 1 and snap["submitted"] == 0


def test_service_cancelled_future_does_not_poison_the_flush():
    """A request cancelled while queued drops out of its flush; the other
    requests in the same batch still resolve normally."""
    g1 = random_bipartite(128, 128, 3.0, seed=13)
    g2 = random_bipartite(130, 130, 3.0, seed=14)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4, max_delay_ms=60.0,
                         adaptive=False) as svc:
        f1 = svc.submit(g1)
        f2 = svc.submit(g2)
        assert f1.cancel()                     # still queued: cancel wins
        res2 = f2.result(timeout=300)          # deadline flush serves g2
        assert res2.cardinality == direct_cardinality(g2)
    assert f1.cancelled()


def test_service_survives_bad_per_request_warm_start():
    """An invalid override fails in the caller's thread; the flush thread
    stays alive and keeps serving."""
    g = random_bipartite(128, 128, 3.0, seed=12)
    with MatchingService(bucketizer=Bucketizer((BUCKET,)), config=CFG,
                         warm_start="cheap", max_batch=4,
                         max_delay_ms=5.0) as svc:
        with pytest.raises(KeyError):
            svc.submit(g, warm_start="not-a-warm-start")
        res = svc.submit(g).result(timeout=300)      # service still serves
        assert res.cardinality == direct_cardinality(g)


def test_synthetic_bucket_graph_shape():
    g = synthetic_bucket_graph(BUCKET)
    assert g.bucket_key == BUCKET.key and int(g.nnz) == 0


# ---------------------------------------------------------------------------
# Compile cache satellites: evictions, capacity override, thread safety
# ---------------------------------------------------------------------------
def test_cache_eviction_counter_and_capacity_override():
    old = set_max_entries(2)
    try:
        before = compile_cache_info()["evictions"]
        for i in range(4):
            get_compiled(("evict-test", i), lambda: (lambda x: x))
        info = compile_cache_info()
        assert info["entries"] <= 2
        assert info["max_entries"] == 2
        assert info["evictions"] >= before + 2
    finally:
        set_max_entries(old)
    assert compile_cache_info()["max_entries"] == old


def test_cache_concurrent_access_is_consistent():
    info0 = compile_cache_info()
    errors = []

    def worker(tid):
        try:
            for i in range(40):
                get_compiled(("thread-test", tid % 2, i),
                             lambda: (lambda x: x))
        except Exception as e:   # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    info1 = compile_cache_info()
    calls = 4 * 40
    assert (info1["hits"] - info0["hits"]
            + info1["misses"] - info0["misses"]) == calls
