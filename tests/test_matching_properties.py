"""Hypothesis property tests for the matcher (skipped without hypothesis).

`hypothesis` is a dev extra (`pip install -e .[dev]`); tier-1 must pass with
or without it, hence the importorskip guard.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (BipartiteCSR, MatcherConfig, cheap_matching_jax,
                        maximum_cardinality, maximum_matching,
                        validate_matching)
from repro.matching import SOLVE_PATHS

CONFIGS = [
    MatcherConfig(algo="apfb", kernel="gpubfs"),
    MatcherConfig(algo="apfb", kernel="gpubfs_wr"),
    MatcherConfig(algo="apsb", kernel="gpubfs"),
    MatcherConfig(algo="apsb", kernel="gpubfs_wr", wr_exact=True),
]


@st.composite
def bip_graphs(draw):
    nc = draw(st.integers(1, 60))
    nr = draw(st.integers(1, 60))
    nnz = draw(st.integers(1, 240))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, nc, size=nnz)
    rows = rng.integers(0, nr, size=nnz)
    return BipartiteCSR.from_edges(cols, rows, nc, nr)


@settings(max_examples=40, deadline=None)
@given(g=bip_graphs(),
       variant=st.sampled_from(range(len(CONFIGS))))
def test_property_maximum_and_valid(g, variant):
    """Any random bipartite graph: result is a VALID matching of MAXIMUM
    cardinality (cardinality is unique even though matchings are not)."""
    cfg = CONFIGS[variant]
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    card = validate_matching(g, cm, rm)
    assert card == opt, stats


@settings(max_examples=15, deadline=None)
@given(g=bip_graphs(), seed=st.integers(0, 100))
def test_property_permutation_invariant_cardinality(g, seed):
    """RCP transform (the paper's second instance set) preserves |M*|."""
    gp = g.permuted(seed)
    assert maximum_cardinality(g) == maximum_cardinality(gp)
    cm, rm, _ = maximum_matching(gp, MatcherConfig())
    assert validate_matching(gp, cm, rm) == maximum_cardinality(g)


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_warm_start_consistent(g):
    """Warm-starting from greedy reaches the same cardinality as cold."""
    cm0, rm0 = cheap_matching_jax(g)
    c_warm, r_warm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, c_warm, r_warm) == maximum_cardinality(g)


@settings(max_examples=25, deadline=None)
@given(g=bip_graphs(), tail=st.integers(1, 6))
def test_property_bounded_tail_reaches_maximum(g, tail):
    """Beyond-paper bounded-tail APFB must still terminate at maximum
    cardinality (the phase-gain guard preserves the invariant)."""
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr", tail_levels=tail)
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    assert validate_matching(g, cm, rm) == opt, stats


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_ks_valid_and_matcher_from_ks(g):
    from repro.core import karp_sipser_jax
    cm0, rm0 = karp_sipser_jax(g)
    validate_matching(g, cm0, rm0)
    cm, rm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


# ---------------------------------------------------------------------------
# ISSUE 7 satellites: container, CSC mirror, and solve-path registry
# ---------------------------------------------------------------------------
@st.composite
def edge_lists(draw):
    nc = draw(st.integers(1, 48))
    nr = draw(st.integers(1, 48))
    nnz = draw(st.integers(1, 192))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return (rng.integers(0, nc, size=nnz), rng.integers(0, nr, size=nnz),
            nc, nr)


@settings(max_examples=30, deadline=None)
@given(e=edge_lists())
def test_property_from_edges_dedup_round_trips(e):
    """from_edges keeps exactly the distinct (col, row) pairs, column-sorted,
    with cxadj consistent with the edge-parallel view."""
    cols, rows, nc, nr = e
    g = BipartiteCSR.from_edges(cols, rows, nc, nr)
    want = {(int(c), int(r)) for c, r in zip(cols, rows)}
    got = list(zip(g.ecol[: g.nnz].tolist(), g.cadj[: g.nnz].tolist()))
    assert set(got) == want and len(got) == g.nnz == len(want)
    assert np.all(np.diff(g.ecol[: g.nnz]) >= 0)
    np.testing.assert_array_equal(
        np.searchsorted(g.ecol[: g.nnz], np.arange(nc + 1)), g.cxadj)
    # padding edges are inert sentinels
    assert np.all(g.ecol[g.nnz:] == nc) and np.all(g.cadj[g.nnz:] == nr)


@settings(max_examples=25, deadline=None)
@given(e=edge_lists())
def test_property_csc_mirror_equals_host_transpose(e):
    """with_csc() == the host transpose, and eperm is a true permutation
    carrying each row-sorted slot back to its CSR edge."""
    from repro.matching import DeviceCSR
    cols, rows, nc, nr = e
    g = BipartiteCSR.from_edges(cols, rows, nc, nr)
    d = DeviceCSR.from_host(g).with_csc()
    t = g.transpose()
    np.testing.assert_array_equal(np.asarray(d.rxadj), t.cxadj)
    np.testing.assert_array_equal(np.asarray(d.radj)[: g.nnz],
                                  t.cadj[: t.nnz])
    np.testing.assert_array_equal(np.asarray(d.erow)[: g.nnz],
                                  t.ecol[: t.nnz])
    perm = np.asarray(d.eperm)
    assert np.array_equal(np.sort(perm), np.arange(g.nnz_pad))
    np.testing.assert_array_equal(np.asarray(d.cadj)[perm],
                                  np.asarray(d.erow))
    np.testing.assert_array_equal(np.asarray(d.ecol)[perm],
                                  np.asarray(d.radj))


@settings(max_examples=12, deadline=None)
@given(e=edge_lists(), path=st.sampled_from(sorted(SOLVE_PATHS)))
def test_property_every_solve_path_valid_and_maximum(e, path):
    """Any registered solve path on any random graph returns a VALID maximum
    matching (fixed pad bucket: one compiled program per path)."""
    cols, rows, nc, nr = e
    g = BipartiteCSR.from_edges(cols, rows, nc, nr)
    cm, rm = SOLVE_PATHS[path].run_host(g, pad=(48, 48, 512))
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)
