"""Hypothesis property tests for the matcher (skipped without hypothesis).

`hypothesis` is a dev extra (`pip install -e .[dev]`); tier-1 must pass with
or without it, hence the importorskip guard.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (BipartiteCSR, MatcherConfig, cheap_matching_jax,
                        maximum_cardinality, maximum_matching,
                        validate_matching)

CONFIGS = [
    MatcherConfig(algo="apfb", kernel="gpubfs"),
    MatcherConfig(algo="apfb", kernel="gpubfs_wr"),
    MatcherConfig(algo="apsb", kernel="gpubfs"),
    MatcherConfig(algo="apsb", kernel="gpubfs_wr", wr_exact=True),
]


@st.composite
def bip_graphs(draw):
    nc = draw(st.integers(1, 60))
    nr = draw(st.integers(1, 60))
    nnz = draw(st.integers(1, 240))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, nc, size=nnz)
    rows = rng.integers(0, nr, size=nnz)
    return BipartiteCSR.from_edges(cols, rows, nc, nr)


@settings(max_examples=40, deadline=None)
@given(g=bip_graphs(),
       variant=st.sampled_from(range(len(CONFIGS))))
def test_property_maximum_and_valid(g, variant):
    """Any random bipartite graph: result is a VALID matching of MAXIMUM
    cardinality (cardinality is unique even though matchings are not)."""
    cfg = CONFIGS[variant]
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    card = validate_matching(g, cm, rm)
    assert card == opt, stats


@settings(max_examples=15, deadline=None)
@given(g=bip_graphs(), seed=st.integers(0, 100))
def test_property_permutation_invariant_cardinality(g, seed):
    """RCP transform (the paper's second instance set) preserves |M*|."""
    gp = g.permuted(seed)
    assert maximum_cardinality(g) == maximum_cardinality(gp)
    cm, rm, _ = maximum_matching(gp, MatcherConfig())
    assert validate_matching(gp, cm, rm) == maximum_cardinality(g)


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_warm_start_consistent(g):
    """Warm-starting from greedy reaches the same cardinality as cold."""
    cm0, rm0 = cheap_matching_jax(g)
    c_warm, r_warm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, c_warm, r_warm) == maximum_cardinality(g)


@settings(max_examples=25, deadline=None)
@given(g=bip_graphs(), tail=st.integers(1, 6))
def test_property_bounded_tail_reaches_maximum(g, tail):
    """Beyond-paper bounded-tail APFB must still terminate at maximum
    cardinality (the phase-gain guard preserves the invariant)."""
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr", tail_levels=tail)
    opt = maximum_cardinality(g)
    cm, rm, stats = maximum_matching(g, cfg)
    assert validate_matching(g, cm, rm) == opt, stats


@settings(max_examples=20, deadline=None)
@given(g=bip_graphs())
def test_property_ks_valid_and_matcher_from_ks(g):
    from repro.core import karp_sipser_jax
    cm0, rm0 = karp_sipser_jax(g)
    validate_matching(g, cm0, rm0)
    cm, rm, _ = maximum_matching(g, MatcherConfig(), cm0, rm0)
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)
