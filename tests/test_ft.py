"""Fault tolerance: step-atomic checkpoints, restart determinism, elastic
restore across different meshes, torn-checkpoint rejection."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, synthetic_batch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.float32(7)}}
    save_checkpoint(str(tmp_path), 3, tree)
    out, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 3
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_latest_and_gc(tmp_path):
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000004", "step_000000005"]


def test_torn_checkpoint_ignored(tmp_path):
    tree = {"x": jnp.zeros(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-save: directory without manifest
    torn = tmp_path / "step_000000002"
    torn.mkdir()
    (torn / "x.npy").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1


def test_data_pipeline_deterministic_and_splittable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    b1 = synthetic_batch(cfg, 7)
    b2 = synthetic_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # 2-host split: concat of host shards == the single-host batch rows
    c0 = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=0)
    c1 = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=1)
    h0, h1 = synthetic_batch(c0, 7), synthetic_batch(c1, 7)
    assert h0["tokens"].shape == (4, 16) and h1["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_elastic_restore_across_meshes(tmp_path):
    """Save sharded on a 4-device mesh, restore onto 2x2 — elastic scaling."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=f"{REPO}/src")
    code = f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import save_checkpoint, restore_checkpoint
x = jnp.arange(64.0).reshape(8, 8)
mesh1 = jax.make_mesh((4,), ("data",))
xs = jax.device_put(x, NamedSharding(mesh1, P("data")))
save_checkpoint(r"{tmp_path}", 1, {{"x": xs}})
mesh2 = jax.make_mesh((2, 2), ("data", "model"))
sh2 = NamedSharding(mesh2, P("data", "model"))
out, step = restore_checkpoint(r"{tmp_path}", {{"x": x}},
                               sharding_tree={{"x": sh2}})
assert step == 1
np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
assert out["x"].sharding.is_equivalent_to(sh2, 2)
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr


@pytest.mark.slow
def test_train_crash_restart_bitexact(tmp_path):
    """Run 6 steps; run 3 steps + hard crash + restart: same final loss."""
    env = dict(os.environ, PYTHONPATH=f"{REPO}/src", JAX_PLATFORMS="cpu")

    def run_train(ckpt, extra):
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
               "mamba2-2.7b", "--smoke", "--batch", "4", "--seq", "64",
               "--mesh", "1", "--steps", "6", "--ckpt-dir", ckpt] + extra
        return subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=580)

    r_gold = run_train(str(tmp_path / "gold"), [])
    assert r_gold.returncode == 0, r_gold.stderr
    gold_losses = [l for l in r_gold.stdout.splitlines() if "loss" in l]

    r_crash = run_train(str(tmp_path / "ft"), ["--simulate-failure", "3"])
    assert r_crash.returncode == 17, (r_crash.returncode, r_crash.stderr)
    r_resume = run_train(str(tmp_path / "ft"), [])
    assert r_resume.returncode == 0, r_resume.stderr
    assert "resumed from step 3" in r_resume.stdout
    resume_final = [l for l in r_resume.stdout.splitlines() if "loss" in l]
    # final-step loss identical to the uninterrupted run
    assert gold_losses[-1].split("loss")[1].split()[0] == \
        resume_final[-1].split("loss")[1].split()[0], \
        (gold_losses[-1], resume_final[-1])
