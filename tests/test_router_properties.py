"""Hypothesis property tests for the MoE routers (skipped without hypothesis).

`hypothesis` is a dev extra (`pip install -e .[dev]`); tier-1 must pass with
or without it, hence the importorskip guard.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.moe import route_matching, route_topk


def _check_feasible(assign, slot, E, C, k):
    assign, slot = np.asarray(assign), np.asarray(slot)
    live = assign >= 0
    loads = np.bincount(assign[live], minlength=E)
    assert loads.max(initial=0) <= C
    pairs = assign[live] * C + slot[live]
    assert len(np.unique(pairs)) == len(pairs), "slot collision"
    for t in range(assign.shape[0]):
        a = assign[t][assign[t] >= 0]
        assert len(set(a.tolist())) == len(a), "duplicate expert in token"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), e_pow=st.integers(2, 4),
       k=st.integers(1, 4), tight=st.floats(0.5, 1.5))
def test_property_router_feasibility(seed, e_pow, k, tight):
    T, E = 128, 2 ** e_pow
    k = min(k, E)
    C = max(2, int(tight * T * k / E))
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    assign, slot, _ = route_matching(logits, k, C)
    _check_feasible(assign, slot, E, C, k)
    a1, s1, _ = route_topk(logits, k, C)
    _check_feasible(a1, s1, E, C, k)
    # matching never routes fewer tokens than greedy
    assert (np.asarray(assign) >= 0).sum() >= (np.asarray(a1) >= 0).sum()
