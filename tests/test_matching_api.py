"""Device-resident repro.matching API: pytree graphs, Matcher, match_many."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (maximum_cardinality, maximum_matching,
                        validate_matching)
from repro.graphs import random_bipartite
from repro.matching import (DeviceCSR, Matcher, MatcherConfig, MatchState,
                            compile_cache_info, match_many,
                            register_warm_start, warm_start_names)
from repro.matching.device_csr import bucket_nnz
from repro.matching.state import empty_like_graph


@pytest.fixture(scope="module")
def g():
    return random_bipartite(200, 180, 3.0, seed=3)


@pytest.fixture(scope="module")
def graph(g):
    return DeviceCSR.from_host(g)


# ---------------------------------------------------------------------------
# DeviceCSR pytree behaviour
# ---------------------------------------------------------------------------
def test_device_csr_flatten_roundtrip(g, graph):
    leaves, treedef = jax.tree.flatten(graph)
    assert all(isinstance(x, jax.Array) for x in leaves)
    back = jax.tree.unflatten(treedef, leaves)
    assert (back.nc, back.nr) == (graph.nc, graph.nr)
    np.testing.assert_array_equal(np.asarray(back.cadj),
                                  np.asarray(graph.cadj))
    host = back.to_host()
    assert host.nnz == g.nnz
    np.testing.assert_array_equal(host.cxadj, g.cxadj)


def test_device_csr_jit_passthrough(graph):
    """A DeviceCSR crosses a jit boundary as a pytree, no host transfer."""
    @jax.jit
    def edge_degree_sum(gr: DeviceCSR):
        return jnp.sum((gr.ecol < gr.nc).astype(jnp.int32))

    assert int(edge_degree_sum(graph)) == int(graph.nnz)


def test_device_csr_pad_and_bucket(g):
    graph = DeviceCSR.from_host(g)
    grown = graph.pad_to(graph.nnz_pad + 256)
    assert grown.nnz_pad == graph.nnz_pad + 256
    assert int(grown.nnz) == g.nnz
    # sentinel padding is inert: same matching as the original bucket
    st_a = Matcher(MatcherConfig()).run(graph)
    st_b = Matcher(MatcherConfig()).run(grown)
    assert int(st_a.cardinality) == int(st_b.cardinality)
    assert bucket_nnz(200) == 256
    assert bucket_nnz(1) == 128
    assert grown.bucketed().nnz_pad == bucket_nnz(grown.nnz_pad)


def test_match_state_roundtrip(g):
    cm = np.full(g.nc, -1, np.int32)
    rm = np.full(g.nr, -1, np.int32)
    cm[3], rm[7] = 7, 3
    st = MatchState.from_host(cm, rm)
    assert int(st.cardinality) == 1
    cm2, rm2 = st.to_host()
    np.testing.assert_array_equal(cm, cm2)
    np.testing.assert_array_equal(rm, rm2)


# ---------------------------------------------------------------------------
# Matcher facade: warm starts, jit closure, zero host hops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ws", ["none", "cheap", "karp_sipser"])
def test_warm_start_registry_parity(g, graph, ws):
    """Every registered warm start composes with the solver to the same
    (maximum) cardinality."""
    st = Matcher(MatcherConfig(), warm_start=ws).run(graph)
    cm, rm = st.to_host()
    assert validate_matching(g, cm, rm) == maximum_cardinality(g)


def test_run_composes_under_jit_end_to_end(g, graph):
    """Acceptance: warm-start init + solve trace into ONE jitted program —
    any host transfer between them would raise a ConcretizationTypeError
    under this outer jax.jit."""
    matcher = Matcher(MatcherConfig(), warm_start="karp_sipser")
    fused = jax.jit(matcher.run)
    st = fused(graph)
    assert isinstance(st.cardinality, jax.Array)   # stats stay on device
    assert int(st.cardinality) == maximum_cardinality(g)
    cm, rm = st.to_host()
    validate_matching(g, cm, rm)


def test_resume_from_state_skips_warm_start(g, graph):
    warm = Matcher(MatcherConfig(), warm_start="cheap").init(graph)
    st = Matcher(MatcherConfig()).run(graph, warm)
    assert int(st.cardinality) == maximum_cardinality(g)


def test_custom_warm_start_registration(g, graph):
    def reversed_greedy(ecol, cadj, cmatch, rmatch):
        return cmatch, rmatch                      # intentionally lazy

    register_warm_start("noop", reversed_greedy)
    assert "noop" in warm_start_names()
    st = Matcher(MatcherConfig(), warm_start="noop").run(graph)
    assert int(st.cardinality) == maximum_cardinality(g)
    with pytest.raises(KeyError):
        Matcher(MatcherConfig(), warm_start="not-a-warm-start")


def test_compile_cache_reuse(graph):
    before = compile_cache_info()
    m = Matcher(MatcherConfig(algo="apsb"), warm_start="cheap")
    m.run(graph)
    mid = compile_cache_info()
    m.run(graph)                                   # same bucket: cache hit
    after = compile_cache_info()
    assert mid["misses"] == before["misses"] + 1
    assert after["misses"] == mid["misses"]
    assert after["hits"] == mid["hits"] + 1


# ---------------------------------------------------------------------------
# match_many — batched serving path
# ---------------------------------------------------------------------------
def test_match_many_agrees_with_looped_maximum_matching():
    """Acceptance: identical cardinalities to looped maximum_matching on an
    8-graph batch."""
    gs = [random_bipartite(128, 128, 3.0, seed=s, pad_to=512)
          for s in range(8)]
    batch = DeviceCSR.stack([DeviceCSR.from_host(x) for x in gs])
    assert batch.batch_shape == (8,)
    out = match_many(batch, MatcherConfig(), warm_start="cheap")
    got = np.asarray(out.cardinality).tolist()
    want = [maximum_matching(x, MatcherConfig())[2]["cardinality"]
            for x in gs]
    assert got == want
    # each batched matching is itself valid
    for i, x in enumerate(gs):
        validate_matching(x, np.asarray(out.cmatch[i])[:-1],
                          np.asarray(out.rmatch[i])[:-1])


def test_match_many_mixed_nnz_same_bucket():
    """Graphs with different true nnz share a bucket via sentinel padding."""
    gs = [random_bipartite(96, 96, d, seed=s)
          for s, d in enumerate((2.0, 5.0, 8.0))]
    batch = DeviceCSR.stack([DeviceCSR.from_host(x) for x in gs])
    out = match_many(batch, warm_start="karp_sipser")
    for i, x in enumerate(gs):
        card = validate_matching(x, np.asarray(out.cmatch[i])[:-1],
                                 np.asarray(out.rmatch[i])[:-1])
        assert card == maximum_cardinality(x)


def test_stacked_state_shapes(graph):
    batch = DeviceCSR.stack([graph, graph])
    st = empty_like_graph(batch)
    assert st.cmatch.shape == (2, graph.nc + 1)
    assert st.phases.shape == (2,)
