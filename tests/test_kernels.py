"""Per-kernel shape/dtype sweeps against the pure-jnp ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cheap_matching_jax
from repro.graphs import random_bipartite, scaled_free
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.frontier_expand import frontier_expand, frontier_expand_ref


def _bfs_state(g, level=2):
    cm, rm = cheap_matching_jax(g)
    nc = g.nc
    cmj = jnp.concatenate([jnp.asarray(cm), jnp.array([-3], jnp.int32)])
    rmj = jnp.concatenate([jnp.asarray(rm), jnp.array([-3], jnp.int32)])
    bfs = jnp.where(cmj >= 0, jnp.int32(1), jnp.int32(2))
    bfs = bfs.at[nc].set(jnp.int32(-(2 ** 30)))
    root = jnp.where(cmj >= 0, jnp.int32(nc),
                     jnp.arange(nc + 1, dtype=jnp.int32))
    return bfs, root, rmj


@pytest.mark.parametrize("nc,nr,deg,pad,blk", [
    (256, 256, 3.0, 1024, 256),
    (500, 700, 4.0, 4096, 512),
    (1000, 1000, 6.0, 8192, 1024),
    (64, 64, 2.0, 128, 128),
    (777, 333, 5.0, 4096, 4096),
])
def test_frontier_expand_matches_ref(nc, nr, deg, pad, blk):
    g = random_bipartite(nc, nr, deg, seed=nc + nr, pad_to=pad)
    bfs, root, rmj = _bfs_state(g)
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    for rt in (root, None):
        out = frontier_expand(ecol, cadj, bfs, rt, rmj, 2, block_edges=blk)
        ref = frontier_expand_ref(ecol, cadj, bfs, rt, rmj, jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_frontier_expand_powerlaw_and_deeper_level():
    g = scaled_free(512, 512, 6.0, seed=3, pad_to=8192)
    bfs, root, rmj = _bfs_state(g)
    # advance one level manually via the ref to get a deeper frontier
    from repro.core.matcher import _expand_level
    bfs2, root2, pred, rm2, ins, aug = _expand_level(
        jnp.asarray(g.ecol), jnp.asarray(g.cadj), bfs, root,
        jnp.full(g.nr + 1, jnp.int32(g.nc)), rmj, jnp.int32(2),
        wr=True, wr_exact=False, use_pallas=False, block_edges=512)
    out = frontier_expand(jnp.asarray(g.ecol), jnp.asarray(g.cadj), bfs2,
                          root2, rm2, 3, block_edges=512)
    ref = frontier_expand_ref(jnp.asarray(g.ecol), jnp.asarray(g.cadj), bfs2,
                              root2, rm2, jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,S,H,KV,hd,causal,bq,bk", [
    (2, 512, 4, 2, 64, True, 128, 128),
    (1, 1024, 8, 8, 128, True, 256, 256),
    (2, 256, 4, 1, 64, False, 128, 128),    # MQA
    (1, 512, 6, 2, 128, True, 512, 256),    # uneven block_q/block_k
    (2, 256, 4, 4, 32, True, 128, 128),     # small head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, KV, hd, causal, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_blockwise_attn_matches_plain():
    """The XLA-level online-softmax path used at long seq == plain softmax."""
    from repro.models.attention import _plain_attn, blockwise_attn
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, KV, hd = 2, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    for kind, win in [("causal", 0), ("swa", 128), ("chunked", 128),
                      ("bidir", 0), ("prefix", 0)]:
        ref = _plain_attn(q, k, v, pos, pos, kind, win, 64)
        out = blockwise_attn(q, k, v, pos, pos, kind, win, 64,
                             q_block=128, kv_block=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
