"""MoE matching router: feasibility, drop-rate dominance, exact reduction.

Hypothesis property tests live in test_router_properties.py (skipped when
hypothesis, a dev extra, is absent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.moe import (route_matching, route_matching_exact, route_topk,
                       router_stats)


def _check_feasible(assign, slot, E, C, k):
    assign, slot = np.asarray(assign), np.asarray(slot)
    live = assign >= 0
    loads = np.bincount(assign[live], minlength=E)
    assert loads.max(initial=0) <= C
    pairs = assign[live] * C + slot[live]
    assert len(np.unique(pairs)) == len(pairs), "slot collision"
    T = assign.shape[0]
    for t in range(T):
        a = assign[t][assign[t] >= 0]
        assert len(set(a.tolist())) == len(a), "duplicate expert in token"


@pytest.mark.parametrize("T,E,k,cf", [
    (256, 8, 2, 1.0), (512, 16, 4, 1.25), (128, 4, 1, 1.0),
    (300, 10, 2, 0.75),
])
def test_routers_feasible(T, E, k, cf):
    C = max(4, int(cf * T * k / E))
    logits = jax.random.normal(jax.random.PRNGKey(T + E), (T, E)) \
        + jnp.linspace(1.5, 0, E)[None]
    for fn in (route_topk, route_matching):
        assign, slot, p = jax.jit(
            lambda l, fn=fn: fn(l, k, C))(logits)
        _check_feasible(assign, slot, E, C, k)
        psum = np.asarray(p).sum(-1)
        live = np.asarray((assign >= 0).any(-1))
        np.testing.assert_allclose(psum[live], 1.0, rtol=1e-4)


def test_matching_beats_greedy_under_skew():
    """The paper's claim transplanted: max-cardinality matching routes more
    tokens than greedy truncation when experts are contended."""
    key = jax.random.PRNGKey(0)
    T, E, k = 512, 16, 4
    C = int(1.0 * T * k / E)
    wins = ties = 0
    for i in range(5):
        key, kk = jax.random.split(key)
        logits = jax.random.normal(kk, (T, E)) + jnp.linspace(2, 0, E)[None]
        a1, _, _ = route_topk(logits, k, C)
        a2, _, _ = route_matching(logits, k, C)
        d1 = router_stats(np.asarray(a1), k)["drop_rate"]
        d2 = router_stats(np.asarray(a2), k)["drop_rate"]
        assert d2 <= d1 + 1e-9, (i, d1, d2)
        wins += d2 < d1 - 1e-9
    assert wins >= 3, "matching router should strictly win on skewed logits"


def test_matching_optimal_vs_exact_small():
    """Against the exact bipartite matcher (paper core) on the instance graph:
    tokens x expert-slots with demand k as k clones."""
    from repro.core import BipartiteCSR, maximum_cardinality
    key = jax.random.PRNGKey(7)
    T, E, k, m = 64, 6, 2, 4
    C = int(0.9 * T * k / E)
    logits = jax.random.normal(key, (T, E)) + jnp.linspace(2, 0, E)[None]
    _, cand = jax.lax.top_k(logits, m)
    cand = np.asarray(cand)
    # exact: columns = token-demand clones, rows = expert slots
    cols, rows = [], []
    for t in range(T):
        for j in range(k):
            for e in cand[t]:
                for s in range(C):
                    cols.append(t * k + j)
                    rows.append(int(e) * C + s)
    g = BipartiteCSR.from_edges(np.array(cols), np.array(rows), T * k, E * C)
    opt_total = maximum_cardinality(g)
    # exact matcher ignores the no-duplicate-expert-per-token constraint, so
    # it is an UPPER bound; the router must land within 10% of it
    assign, _, _ = route_matching(logits, k, C, n_cand=m, aug_phases=4)
    got = int((np.asarray(assign) >= 0).sum())
    assert got >= 0.9 * opt_total, (got, opt_total)


def test_exact_router_feasible_and_dominates():
    """route_matching_exact (gadget reduction onto the paper's matcher,
    composed through the device API under jit) is feasible and never drops
    more than greedy truncation or the approximate augmenting router."""
    T, E, k, m = 64, 6, 2, 4
    C = int(0.9 * T * k / E)
    logits = jax.random.normal(jax.random.PRNGKey(7), (T, E)) \
        + jnp.linspace(2, 0, E)[None]
    assign, slot, p = jax.jit(
        lambda l: route_matching_exact(l, k, C, n_cand=m))(logits)
    _check_feasible(assign, slot, E, C, k)
    psum = np.asarray(p).sum(-1)
    live = np.asarray((assign >= 0).any(-1))
    np.testing.assert_allclose(psum[live], 1.0, rtol=1e-4)
    d_exact = router_stats(np.asarray(assign), k)["drop_rate"]
    a1, _, _ = route_topk(logits, k, C)
    a2, _, _ = route_matching(logits, k, C, n_cand=m, aug_phases=4)
    assert d_exact <= router_stats(np.asarray(a1), k)["drop_rate"] + 1e-9
    assert d_exact <= router_stats(np.asarray(a2), k)["drop_rate"] + 1e-9
