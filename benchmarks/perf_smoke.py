"""§Perf-smoke: the level-sweep microbench + solve bench behind the repo's
committed perf baseline (``BENCH_PR7.json``).

Every row carries a machine-portable ``rel`` ratio (path time over the jnp
path's time on the same input) so the CI regression gate compares relative
numbers rather than absolute wall-clock across hosts; the gate reads the
``sweep_summary`` (geomean over graphs) and ``solve`` sets — per-graph
sub-millisecond detail rows are for humans, too noisy to gate on.  Row sets:

* ``perf_smoke.sweep`` — ONE BFS level of frontier expansion (the O(nnz) hot
  loop of Figs. 2-5) through each winner path: ``jnp`` (proposals + XLA
  scatter), ``pallas_legacy`` (proposal kernel + XLA scatter),
  ``pallas_fused`` (in-kernel winner merge) and ``pallas_pull`` (the
  direction-optimizing pull kernel streaming the CSC mirror).  On CPU hosts
  the Pallas paths run through the interpreter (``mode=interpret``); on
  accelerator backends the same rows carry ``mode=compiled`` — the fused
  compiled path is the one the paper's speedup story rests on.
* ``perf_smoke.solve`` — full ``Matcher.run`` geomeans per sweep config
  (includes the beyond-paper ``adaptive_frontier`` and ``dirop``
  dispatches).

Run directly, or through the harness + regression gate (refresh the
committed baseline with ``--update-baseline``, never by hand):

    python -m benchmarks.run --only perf_smoke --scale tiny \
        --json bench_new.json --baseline BENCH_PR7.json
    python -m benchmarks.run --only perf_smoke,corpus --scale tiny \
        --update-baseline BENCH_PR7.json --runs 3
"""
from __future__ import annotations

import functools
import sys
from typing import List

import jax
import jax.numpy as jnp

from repro.core import MatcherConfig, cheap_matching_jax
from repro.graphs import random_bipartite, scaled_free
from repro.kernels.frontier_expand import (frontier_expand,
                                           frontier_expand_fused,
                                           frontier_expand_fused_ref,
                                           frontier_expand_pull,
                                           resolve_interpret)
from repro.matching.device_csr import DeviceCSR
from repro.matching.solve import (IINF, default_block_edges, level0_state,
                                  scatter_min)
from .common import geomean, time_call, time_matcher

_SCALES = {
    "tiny": [("rand", lambda: random_bipartite(512, 512, 3.0, seed=1)),
             ("free", lambda: scaled_free(512, 512, 4.0, seed=2))],
    "small": [("rand", lambda: random_bipartite(4096, 4096, 4.0, seed=1)),
              ("free", lambda: scaled_free(4096, 4096, 6.0, seed=2))],
    "large": [("rand", lambda: random_bipartite(20000, 20000, 4.0, seed=1)),
              ("free", lambda: scaled_free(20000, 20000, 6.0, seed=2))],
}


def _sweep_state(g):
    """Level-L0 BFS state from the cheap matching — built by the solver's
    own ``level0_state`` init so the probe cannot drift from what the
    solver actually sweeps."""
    cm, rm = cheap_matching_jax(g)
    cmj = jnp.concatenate([jnp.asarray(cm), jnp.array([-3], jnp.int32)])
    rmj = jnp.concatenate([jnp.asarray(rm), jnp.array([-3], jnp.int32)])
    bfs, root = level0_state(cmj)
    return jnp.asarray(g.ecol), jnp.asarray(g.cadj), bfs, root, rmj


def _csc_arrays(g):
    """The row-sorted (radj, erow) mirror the pull kernel streams."""
    d = DeviceCSR.from_host(g).with_csc()
    return d.radj, d.erow


# the rel denominator: the SAME proposals + per-row min-merge oracle the
# kernels are tested against, jitted — reimplementing the formula here
# would let the committed baseline drift from the solver's real jnp path
_jnp_winner = jax.jit(frontier_expand_fused_ref)


def _sweep_paths(interpret: bool):
    """path name -> winner fn(ecol, cadj, bfs, root, rmj, blk).

    Each path is ONE jitted dispatch (the legacy kernel + its XLA merge are
    jitted together), so rel ratios measure the sweeps, not eager-dispatch
    overhead one competitor happens to pay.
    """
    @functools.partial(jax.jit, static_argnames=("blk",))
    def legacy(ecol, cadj, bfs, root, rmj, *, blk):
        nr = rmj.shape[0] - 1
        prop = frontier_expand(ecol, cadj, bfs, root, rmj, 2,
                               block_edges=blk, interpret=interpret)
        return scatter_min(nr, jnp.where(prop < IINF, cadj, nr), prop)

    @functools.partial(jax.jit, static_argnames=("blk",))
    def fused(ecol, cadj, bfs, root, rmj, *, blk):
        return frontier_expand_fused(ecol, cadj, bfs, root, rmj, 2,
                                     block_edges=blk, interpret=interpret)

    @functools.partial(jax.jit, static_argnames=("blk",))
    def pull(radj, erow, bfs, root, rmj, *, blk):
        # same winner contract, CSC edge stream (row-sorted tiles whose
        # merge skips when the tile proposes nothing)
        return frontier_expand_pull(radj, erow, bfs, root, rmj, 2,
                                    block_edges=blk, interpret=interpret)

    return {"pallas_legacy": legacy, "pallas_fused": fused,
            "pallas_pull": pull}


def run(scale: str = "tiny") -> List[str]:
    backend = jax.default_backend()
    interpret = resolve_interpret(None)
    mode = "interpret" if interpret else "compiled"
    rows = ["perf_smoke.sweep,backend,mode,graph,path,block_edges,ms,rel"]
    reps = 20                       # sweeps per timed call: sub-ms kernels
    rels = {}                       # would make the rel gate flaky
    for gname, build in _SCALES[scale]:
        g = build()
        ecol, cadj, bfs, root, rmj = _sweep_state(g)
        radj, erow = _csc_arrays(g)
        blk = default_block_edges(int(ecol.shape[0]), "ct")

        def timed(fn):
            fn()                    # compile (not timed)
            def many():
                for _ in range(reps):
                    out = fn()
                jax.block_until_ready(out)
            return time_call(many, repeat=5) / reps

        base = timed(lambda: _jnp_winner(ecol, cadj, bfs, root, rmj,
                                         jnp.int32(2)))
        rows.append(f"perf_smoke.sweep,{backend},xla,{gname},jnp,-,"
                    f"{base*1e3:.3f},1.000")
        for pname, fn in _sweep_paths(interpret).items():
            ea, eb = (radj, erow) if pname == "pallas_pull" else (ecol, cadj)
            t = timed(lambda: fn(ea, eb, bfs, root, rmj, blk=blk))
            rows.append(f"perf_smoke.sweep,{backend},{mode},{gname},{pname},"
                        f"{blk},{t*1e3:.3f},{t/base:.3f}")
            rels.setdefault(pname, []).append(t / base)

    # the gate rows: geomean over graphs is far less noisy than any one
    # sub-ms measurement (benchmarks/run.py GATED_SETS)
    rows.append("perf_smoke.sweep_summary,backend,mode,path,rel")
    for pname, rs in rels.items():
        rows.append(f"perf_smoke.sweep_summary,{backend},{mode},{pname},"
                    f"{geomean(rs):.3f}")

    rows.append("perf_smoke.solve,backend,mode,config,geomean_ms,rel")
    solve_cases = [
        ("jnp", MatcherConfig(algo="apfb", kernel="gpubfs_wr")),
        ("pallas_fused", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                       use_pallas=True)),
        ("pallas_legacy", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                        use_pallas=True, pallas_fused=False)),
        ("adaptive", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                   adaptive_frontier=True)),
        ("dirop", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                dirop=True)),
    ]
    insts = [(n, b()) for n, b in _SCALES[scale]]
    prepared = [(n, g, *cheap_matching_jax(g)) for n, g in insts]
    base_ms = None
    for cname, cfg in solve_cases:
        times = [time_matcher(g, cfg, cm0, rm0, repeat=3)[0]
                 for _, g, cm0, rm0 in prepared]
        ms = geomean(times) * 1e3
        if base_ms is None:
            base_ms = ms
        m = "xla" if not cfg.use_pallas else mode
        rows.append(f"perf_smoke.solve,{backend},{m},{cname},{ms:.2f},"
                    f"{ms/base_ms:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run(sys.argv[1] if len(sys.argv) > 1 else "tiny")))
