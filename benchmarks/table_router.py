"""Framework-integration benchmark: matching router vs greedy top-k router.

Drop rate and wall time across contention regimes — the paper's
maximum-cardinality objective applied to MoE dispatch (DESIGN.md §4).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.moe import route_matching, route_topk, router_stats


def run(scale: str = "tiny") -> List[str]:
    T = {"tiny": 1024, "small": 8192, "large": 65536}[scale]
    rows = ["router.case,router,drop_rate,ms_per_call"]
    cases = [
        ("E16_k4_cf1.0_skew", 16, 4, 1.0, 2.0),
        ("E64_k2_cf1.0_skew", 64, 2, 1.0, 2.0),
        ("E128_k1_cf1.25_skew", 128, 1, 1.25, 2.0),
        ("E16_k4_cf1.25_uniform", 16, 4, 1.25, 0.0),
    ]
    for name, E, k, cf, skew in cases:
        C = max(8, int(cf * T * k / E))
        key = jax.random.PRNGKey(hash(name) % 2**31)
        logits = jax.random.normal(key, (T, E)) \
            + skew * jnp.linspace(1, 0, E)[None]
        for rname, fn in (("topk", route_topk), ("matching", route_matching)):
            jfn = jax.jit(lambda l, fn=fn: fn(l, k, C))
            a, s, p = jfn(logits)
            jax.block_until_ready(a)
            t0 = time.perf_counter()
            for _ in range(5):
                a, s, p = jfn(logits)
            jax.block_until_ready(a)
            dt = (time.perf_counter() - t0) / 5
            st = router_stats(np.asarray(a), k)
            rows.append(f"{name},{rname},{st['drop_rate']:.4f},{dt*1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
