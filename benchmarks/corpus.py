"""§Corpus: per-family dirop win/loss + the deterministic heuristic gate.

The paper's evaluation spans instance *families* (road, Kronecker,
web/social, LP, plus RCP-permuted copies) precisely because algorithm
win/loss flips per family; a single-family gate cannot see an
``alpha``/``beta`` regression that only hurts, say, the road-like
instances.  This bench records, per corpus family × {orig, rcp}:

* ``corpus.family`` — measured wall-clock ``rel`` of the
  direction-optimizing matcher vs the push-only matcher (informational:
  timing rows are too host-noisy to gate);
* ``corpus.heuristic`` — the **gated** rows: the deterministic modelled
  ``rel`` of the dirop decisions at the shipped defaults, from
  :mod:`repro.corpus.heuristic`'s exact replay + tile work model.  A
  broken ``dirop_alpha``/``dirop_beta`` moves these rows far past any gate
  tolerance, and they are bit-reproducible across hosts;
* ``corpus.heuristic_detail`` — pull/level counts behind each gated row;
* ``corpus.alpha_sweep`` (+``_summary``) — the committed (alpha, beta)
  sweep the :class:`~repro.matching.MatcherConfig` dirop defaults cite.

Through the harness + gate::

    python -m benchmarks.run --only corpus --scale tiny \
        --json bench_new.json --baseline BENCH_PR7.json
    python -m benchmarks.run --only perf_smoke,corpus --scale tiny \
        --update-baseline BENCH_PR7.json --runs 3
"""
from __future__ import annotations

import sys
from typing import Dict, List, Optional, Tuple

import jax

from repro.core import MatcherConfig
from repro.core.csr import BipartiteCSR
from repro.corpus.heuristic import (LANE, PULL_TILE_OVERHEAD, HeuristicTrace,
                                    modelled_rel, sweep_grid, trace_instance)
from repro.corpus.verify import corpus_instances, shared_bucket
from repro.matching import DeviceCSR, Matcher

from .common import geomean, time_call

PUSH = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
DIROP = MatcherConfig(algo="apfb", kernel="gpubfs_wr", dirop=True)


def _split(name: str) -> Tuple[str, str]:
    return (name[:-4], "rcp") if name.endswith("_rcp") else (name, "orig")


def family_rows(insts: Dict[str, BipartiteCSR], repeat: int = 3) -> List[str]:
    """Measured dirop-vs-push timing per family.

    Every instance is padded into one shared bucket so the whole set runs on
    two compiled programs (push, dirop) — and the two matchers sweep the
    same padded edge count, so ``rel`` isolates the direction decisions.
    """
    backend = jax.default_backend()
    pad = shared_bucket(insts.values())
    rows = ["corpus.family,backend,family,set,push_ms,dirop_ms,rel"]
    push_m = Matcher(PUSH, warm_start="cheap")
    dirop_m = Matcher(DIROP, warm_start="cheap")
    for name, g in insts.items():
        base = (DeviceCSR.from_host(g)
                .pad_vertices(pad[0], pad[1]).pad_to(pad[2]))
        csc = base.with_csc()

        def timed(m, gr):
            jax.block_until_ready(m.run(gr).cmatch)        # compile, untimed
            return time_call(
                lambda: jax.block_until_ready(m.run(gr).cmatch), repeat)

        tp, td = timed(push_m, base), timed(dirop_m, csc)
        fam, s = _split(name)
        rows.append(f"corpus.family,{backend},{fam},{s},{tp*1e3:.2f},"
                    f"{td*1e3:.2f},{td/tp:.3f}")
    return rows


def heuristic_traces(insts: Dict[str, BipartiteCSR]
                     ) -> Dict[str, HeuristicTrace]:
    return {n: trace_instance(g) for n, g in insts.items()}


def heuristic_rows(insts: Dict[str, BipartiteCSR],
                   traces: Optional[Dict[str, HeuristicTrace]] = None,
                   alpha: float = DIROP.dirop_alpha,
                   beta: float = DIROP.dirop_beta,
                   ) -> Tuple[List[str], Dict[str, HeuristicTrace]]:
    """The gated deterministic rows (plus detail), at the given thresholds.

    Exposed with explicit ``alpha``/``beta`` so tests can demonstrate the
    gate catching a deliberately broken heuristic without touching config.
    """
    if traces is None:
        traces = heuristic_traces(insts)
    rows = [f"# corpus.heuristic model: LANE={LANE} "
            f"PULL_TILE_OVERHEAD={PULL_TILE_OVERHEAD} "
            f"alpha={alpha:g} beta={beta:g}",
            "corpus.heuristic,family,set,rel"]
    detail = ["corpus.heuristic_detail,family,set,alpha,beta,pulls,levels,rel"]
    for name, tr in traces.items():
        rel, pulls = modelled_rel(tr, alpha, beta)
        fam, s = _split(name)
        rows.append(f"corpus.heuristic,{fam},{s},{rel:.3f}")
        detail.append(f"corpus.heuristic_detail,{fam},{s},{alpha:g},{beta:g},"
                      f"{pulls},{tr.levels},{rel:.3f}")
    return rows + detail, traces


def sweep_rows(traces: Dict[str, HeuristicTrace]) -> List[str]:
    """The committed (alpha, beta) sweep + its geomean summary — what the
    shipped dirop defaults cite."""
    rows = ["corpus.alpha_sweep,family,set,alpha,beta,rel"]
    geo: Dict[Tuple[float, float], List[float]] = {}
    for name, tr in traces.items():
        fam, s = _split(name)
        for a, b in sweep_grid():
            rel, _ = modelled_rel(tr, a, b)
            rows.append(f"corpus.alpha_sweep,{fam},{s},{a:g},{b:g},{rel:.3f}")
            geo.setdefault((a, b), []).append(rel)
    rows.append("corpus.alpha_sweep_summary,alpha,beta,rel")
    for (a, b), rels in geo.items():
        rows.append(f"corpus.alpha_sweep_summary,{a:g},{b:g},"
                    f"{geomean(rels):.3f}")
    rows.append(f"# sweep basis for the MatcherConfig dirop defaults "
                f"alpha={DIROP.dirop_alpha:g}/beta={DIROP.dirop_beta:g}")
    return rows


def run(scale: str = "tiny") -> List[str]:
    insts = corpus_instances(scale=scale, rcp=True)
    rows = family_rows(insts)
    hrows, traces = heuristic_rows(insts)
    return rows + hrows + sweep_rows(traces)


if __name__ == "__main__":
    print("\n".join(run(sys.argv[1] if len(sys.argv) > 1 else "tiny")))
