"""Batched serving throughput: ``match_many`` vs a per-graph loop.

The vmap path solves a whole bucket of independent graphs in one compiled
dispatch — the first step toward serving many concurrent matching requests
(ROADMAP north star).  Reports per-graph latency for both paths and the
resulting speedup, per batch size.

Caveat: under vmap the batched while_loops run in lock-step (every graph
pays for the slowest), so on a single CPU device the ratio can dip below 1;
the dispatch-count win shows on wide accelerators and in serving loops where
per-call overhead dominates.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.graphs import random_bipartite
from repro.matching import DeviceCSR, Matcher, MatcherConfig

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    n = {"tiny": 256, "small": 2048, "large": 16384}[scale]
    pad = {"tiny": 1024, "small": 8192, "large": 65536}[scale]
    rows = ["batch.batch_size,loop_ms_per_graph,vmap_ms_per_graph,speedup"]
    matcher = Matcher(BEST, warm_start="cheap")
    for bs in (2, 8, 32):
        graphs = [DeviceCSR.from_host(
            random_bipartite(n, n, 4.0, seed=s, pad_to=pad))
            for s in range(bs)]
        batch = DeviceCSR.stack(graphs)
        # warmup both paths (compile)
        loop_out = [matcher.run(g) for g in graphs]
        jax.block_until_ready([s.cmatch for s in loop_out])
        many = matcher.run_many(batch)
        jax.block_until_ready(many.cmatch)
        assert (np.asarray(many.cardinality).tolist()
                == [int(s.cardinality) for s in loop_out])

        t0 = time.perf_counter()
        jax.block_until_ready([matcher.run(g).cmatch for g in graphs])
        t_loop = (time.perf_counter() - t0) / bs
        t0 = time.perf_counter()
        jax.block_until_ready(matcher.run_many(batch).cmatch)
        t_vmap = (time.perf_counter() - t0) / bs
        rows.append(f"{bs},{t_loop*1e3:.2f},{t_vmap*1e3:.2f},"
                    f"{t_loop/max(t_vmap, 1e-9):.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
