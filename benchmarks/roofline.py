"""Roofline table from the dry-run artifacts (docs/architecture.md,
"LM-substrate notes").

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
per-(arch x shape x mesh): the three roofline terms in seconds, the dominant
term, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and roofline fraction
(model-flops time at peak / dominant-term time — the score the perf loop
drives up).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def load_records(dirname: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Dict:
    t = rec["terms"]
    dominant = max(t, key=t.get)
    ndev = rec["devices"]
    # model_flops is whole-cluster useful work; per-device share:
    useful_s = rec["model_flops"] / ndev / PEAK
    bound_s = max(t.values())
    frac = useful_s / bound_s if bound_s > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_ms": t["compute_s"] * 1e3,
        "memory_ms": t["memory_s"] * 1e3,
        "collective_ms": t["collective_s"] * 1e3,
        "dominant": dominant.replace("_s", ""),
        "useful_ratio": rec["model_flops"] / ndev / max(rec["flops_total"], 1),
        "roofline_frac": frac,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reduce resharding: keep activations on one layout across "
                "blocks / overlap all-gathers with the scanned matmuls")
    if d == "memory":
        return ("raise arithmetic intensity: larger fused blocks, bf16 "
                "cache reads, avoid materializing masked score tensors")
    return "already compute-bound: only kernel-level MXU utilization remains"


def run(scale: str = "") -> List[str]:
    rows = ["roofline.arch,shape,mesh,tag,compute_ms,memory_ms,"
            "collective_ms,dominant,useful_ratio,roofline_frac,peak_GiB"]
    for rec in load_records():
        if rec.get("status") != "ok":
            continue
        r = roofline_row(rec)
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['tag']},"
            f"{r['compute_ms']:.2f},{r['memory_ms']:.2f},"
            f"{r['collective_ms']:.2f},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['roofline_frac']:.3f},"
            f"{r['peak_gib']:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
