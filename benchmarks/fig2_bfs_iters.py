"""Paper Figure 2: per-phase BFS level counts for APsB vs APFB.

Instrumented re-execution of the phase loop (python outer loop over the same
jitted level-expansion) on a grid instance (long paths, Hamrle3-like regime)
and a random instance (short paths, Delaunay-like regime is the converse).
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import cheap_matching_jax
from repro.core.matcher import (FOUND, L0, NEG, UNVISITED, _alternate,
                                _cardinality, _expand_level, _fix_matching)
from repro.graphs import grid_graph, random_bipartite


def instrumented_phases(g, algo: str, max_phases: int = 10_000):
    """Returns list of per-phase BFS level counts (the y-axis of Fig. 2)."""
    nc, nr = g.nc, g.nr
    cm0, rm0 = cheap_matching_jax(g)
    cmatch = jnp.concatenate([jnp.asarray(cm0), jnp.array([-3], jnp.int32)])
    rmatch = jnp.concatenate([jnp.asarray(rm0), jnp.array([-3], jnp.int32)])
    ecol, cadj = jnp.asarray(g.ecol), jnp.asarray(g.cadj)
    cols = jnp.arange(nc + 1, dtype=jnp.int32)
    levels_per_phase: List[int] = []
    for _ in range(max_phases):
        bfs = jnp.where(cmatch >= 0, UNVISITED, L0).at[nc].set(NEG)
        root = jnp.where(cmatch >= 0, jnp.int32(nc), cols)
        pred = jnp.full(nr + 1, jnp.int32(nc), jnp.int32)
        level = L0
        aug = False
        nlev = 0
        while True:
            bfs, root, pred, rmatch, ins, aug_l = _expand_level(
                ecol, cadj, bfs, root, pred, rmatch, level, wr=True,
                wr_exact=False, use_pallas=False, block_edges=4096)
            nlev += 1
            aug = aug or bool(aug_l)
            level = level + 1
            if algo == "apsb" and aug:
                break
            if not bool(ins):
                break
        levels_per_phase.append(nlev)
        if not aug:
            break
        card0 = _cardinality(cmatch)
        mask = rmatch == -2
        cm1, rm1, _ = _alternate(cmatch, rmatch, pred,
                                 mask, jnp.int32(2 * (min(nc, nr) + 2)))
        cm1, rm1 = _fix_matching(cm1, rm1)
        if int(_cardinality(cm1)) <= int(card0):
            first = jnp.argmax(mask)
            one = jnp.zeros(nr + 1, bool).at[first].set(jnp.any(mask))
            cm1, rm1, _ = _alternate(cmatch, jnp.where(mask, -1, rmatch),
                                     pred, one,
                                     jnp.int32(2 * (min(nc, nr) + 2)))
            cm1, rm1 = _fix_matching(cm1, rm1)
        cmatch, rmatch = cm1, rm1
    return levels_per_phase


def run(scale: str = "tiny") -> List[str]:
    side = {"tiny": 24, "small": 64, "large": 128}[scale]
    n = {"tiny": 1024, "small": 16384, "large": 1 << 18}[scale]
    graphs = {
        "grid(road-like)": grid_graph(side),
        "rand(delaunay-like)": random_bipartite(n, n, 4.0, seed=2),
    }
    rows = ["fig2.graph,algo,phases,total_levels,levels_per_phase"]
    for gname, g in graphs.items():
        for algo in ("apfb", "apsb"):
            lv = instrumented_phases(g, algo)
            prof = ";".join(str(x) for x in lv[:40])
            rows.append(f"{gname},{algo},{len(lv)},{sum(lv)},{prof}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
