"""Shared benchmark helpers: timing + the paper's instance methodology.

Methodology mirrors the paper: all matchers start from the same cheap
matching (not timed); the JAX matchers are compiled once per shape bucket
(warmup run, not timed); sequential baselines are Hopcroft-Karp and
Pothen-Fan in numpy/python plus scipy's C Hopcroft-Karp (``HK-C``) as the
strong sequential baseline.  Instances: the synthetic suite standing in for
the UFL classes (see repro.graphs.generators), original + RCP (permuted).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import (MatcherConfig, cheap_matching_jax, hopcroft_karp,
                        maximum_matching, pfp, push_relabel,
                        validate_matching)
from repro.core.csr import BipartiteCSR


def time_call(fn: Callable, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def time_matcher(g: BipartiteCSR, cfg: MatcherConfig, cm0, rm0,
                 repeat: int = 3) -> Tuple[float, dict]:
    # warmup (compile)
    cm, rm, stats = maximum_matching(g, cfg, cm0, rm0)
    t = time_call(lambda: maximum_matching(g, cfg, cm0, rm0), repeat)
    return t, stats


def time_sequential(g: BipartiteCSR, cm0, rm0) -> Dict[str, float]:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    out = {}
    t0 = time.perf_counter()
    hopcroft_karp(g, cm0, rm0)
    out["HK"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    pfp(g, cm0, rm0)
    out["PFP"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    push_relabel(g, cm0, rm0)
    out["PR"] = time.perf_counter() - t0
    m = sp.csr_matrix((np.ones(g.nnz, np.int8), g.cadj[: g.nnz], g.cxadj),
                      shape=(g.nc, g.nr))
    t0 = time.perf_counter()
    maximum_bipartite_matching(m, perm_type="column")
    out["HK-C"] = time.perf_counter() - t0
    return out


def geomean(xs: List[float]) -> float:
    xs = [max(x, 1e-9) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))


def prepared_instances(scale: str, rcp: bool, seed: int = 13):
    from repro.graphs import instance_sets
    out = {}
    for name, g in instance_sets(scale).items():
        gg = g.permuted(seed) if rcp else g
        cm0, rm0 = cheap_matching_jax(gg)
        out[name] = (gg, cm0, rm0)
    return out
