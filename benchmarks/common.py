"""Shared benchmark helpers: timing + the paper's instance methodology.

Methodology mirrors the paper: all matchers start from the same cheap
matching (not timed); the JAX matchers are compiled once per shape bucket
(warmup run, not timed); sequential baselines are Hopcroft-Karp and
Pothen-Fan in numpy/python plus scipy's C Hopcroft-Karp (``HK-C``) as the
strong sequential baseline.  Instances: the synthetic suite standing in for
the UFL classes (see repro.graphs.generators), original + RCP (permuted).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core import (MatcherConfig, cheap_matching_jax, hopcroft_karp,
                        pfp, push_relabel)
from repro.core.csr import BipartiteCSR
from repro.matching import DeviceCSR, Matcher, MatchState


def time_call(fn: Callable, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def time_matcher(g: BipartiteCSR, cfg: MatcherConfig, cm0, rm0,
                 repeat: int = 3) -> Tuple[float, dict]:
    """Device-resident timing: graph + warm-start state upload once (not
    timed), then each repeat is one compiled solver dispatch, synced."""
    graph = DeviceCSR.from_host(g)
    if cfg.dirop:
        graph = graph.with_csc()     # mirror built once, outside the timing
    state0 = MatchState.from_host(np.asarray(cm0, np.int32),
                                  np.asarray(rm0, np.int32))
    matcher = Matcher(cfg)
    out = matcher.run(graph, state0)                    # warmup (compile)
    jax.block_until_ready((out.cmatch, out.rmatch))
    t = time_call(
        lambda: jax.block_until_ready(matcher.run(graph, state0).cmatch),
        repeat)
    return t, matcher.stats(out).as_dict()


def time_sequential(g: BipartiteCSR, cm0, rm0) -> Dict[str, float]:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    out = {}
    t0 = time.perf_counter()
    hopcroft_karp(g, cm0, rm0)
    out["HK"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    pfp(g, cm0, rm0)
    out["PFP"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    push_relabel(g, cm0, rm0)
    out["PR"] = time.perf_counter() - t0
    m = sp.csr_matrix((np.ones(g.nnz, np.int8), g.cadj[: g.nnz], g.cxadj),
                      shape=(g.nc, g.nr))
    t0 = time.perf_counter()
    maximum_bipartite_matching(m, perm_type="column")
    out["HK-C"] = time.perf_counter() - t0
    return out


def geomean(xs: List[float]) -> float:
    xs = [max(x, 1e-9) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))


def prepared_instances(scale: str, rcp: bool, seed: int = 13):
    from repro.graphs import instance_sets
    out = {}
    for name, g in instance_sets(scale).items():
        gg = g.permuted(seed) if rcp else g
        cm0, rm0 = cheap_matching_jax(gg)
        out[name] = (gg, cm0, rm0)
    return out
