"""Sharded vs single-device matching sweep (``ShardedMatcher`` scale-out).

Times the edge-partitioned ``ShardedMatcher`` (one pmin per BFS level)
against the single-device ``Matcher`` on the same instances, asserting equal
cardinality.  On a real multi-chip mesh the sharded column shows the scale-out
curve; on a forced-host CPU mesh it mostly prices the collective overhead
(docs/architecture.md, "ShardedMatcher").

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.sharded_matching
"""
from __future__ import annotations

import os
import sys
from typing import List

if __name__ == "__main__":                 # forced mesh only when standalone:
    os.environ.setdefault(                 # under benchmarks.run JAX is
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")  # already up

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.graphs import random_bipartite  # noqa: E402
from repro.matching import (DeviceCSR, Matcher, MatcherConfig,  # noqa: E402
                            ShardedMatcher)

from .common import time_call  # noqa: E402

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    sizes = {"tiny": (512, 2048), "small": (2048, 8192),
             "large": (8192, 32768)}[scale]
    ndev = jax.device_count()
    mesh = jax.make_mesh((ndev,), ("data",))
    single = Matcher(BEST, warm_start="cheap")
    sharded = ShardedMatcher(mesh, config=BEST, warm_start="cheap")
    rows = [f"sharded.n,devices,single_ms,sharded_ms,ratio,edges_per_dev"]
    for n in sizes:
        g = random_bipartite(n, n, 4.0, seed=7)
        graph = DeviceCSR.from_host(g)
        sharded_g = graph.shard(mesh, "data")
        s1 = single.run(graph)                       # warmup (compile)
        s2 = sharded.run(sharded_g)
        assert int(s1.cardinality) == int(s2.cardinality), \
            (n, int(s1.cardinality), int(s2.cardinality))
        t1 = time_call(
            lambda: jax.block_until_ready(single.run(graph).cmatch))
        t2 = time_call(
            lambda: jax.block_until_ready(sharded.run(sharded_g).cmatch))
        rows.append(f"{n},{ndev},{t1*1e3:.2f},{t2*1e3:.2f},"
                    f"{t1/max(t2, 1e-9):.2f},{sharded_g.nnz_pad // ndev}")
    return rows


if __name__ == "__main__":
    print("\n".join(run(sys.argv[1] if len(sys.argv) > 1 else "tiny")))
