"""§Perf iteration log for the matcher itself (hypothesis -> change ->
measure).  Run at --scale small for meaningful times:

    PYTHONPATH=src python -m benchmarks.perf_matcher [small|large]

Covers: (1) paper-faithful variant baselines, (2) the beyond-paper
bounded-tail APFB sweep (interpolating APsB <-> APFB), (3) level/phase work
accounting that explains the wins, (4) the frontier-sweep execution paths
(fused Pallas kernel, legacy two-step Pallas, frontier-adaptive dispatch —
the per-level microbench behind these lives in benchmarks/perf_smoke.py).
"""
from __future__ import annotations

import sys
from typing import List

from repro.core import MatcherConfig
from .common import geomean, prepared_instances, time_matcher


def run(scale: str = "small") -> List[str]:
    rows = ["perf_matcher.set,config,geomean_ms,phases_total"]
    for rcp in (False, True):
        label = "RCP" if rcp else "orig"
        insts = prepared_instances(scale, rcp)
        cases = [
            ("apsb-wr (paper)", MatcherConfig(algo="apsb",
                                              kernel="gpubfs_wr",
                                              wr_exact=True)),
            ("apfb-wr (paper best)", MatcherConfig(algo="apfb",
                                                   kernel="gpubfs_wr")),
            ("apfb-plain tail=0", MatcherConfig(algo="apfb",
                                                kernel="gpubfs")),
            ("apfb-wr tail=2", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                             tail_levels=2)),
            ("apfb-wr tail=4", MatcherConfig(algo="apfb", kernel="gpubfs_wr",
                                             tail_levels=4)),
            ("apfb-plain tail=2", MatcherConfig(algo="apfb", kernel="gpubfs",
                                                tail_levels=2)),
            ("apfb-plain tail=4", MatcherConfig(algo="apfb", kernel="gpubfs",
                                                tail_levels=4)),
            ("apfb-wr pallas-fused", MatcherConfig(algo="apfb",
                                                   kernel="gpubfs_wr",
                                                   use_pallas=True)),
            ("apfb-wr pallas-legacy", MatcherConfig(algo="apfb",
                                                    kernel="gpubfs_wr",
                                                    use_pallas=True,
                                                    pallas_fused=False)),
            ("apfb-wr adaptive", MatcherConfig(algo="apfb",
                                               kernel="gpubfs_wr",
                                               adaptive_frontier=True)),
        ]
        for cname, cfg in cases:
            times, phases = [], 0
            for name, (g, cm0, rm0) in insts.items():
                t, st = time_matcher(g, cfg, cm0, rm0, repeat=2)
                times.append(t)
                phases += st["phases"]
            rows.append(f"{label},{cname},{geomean(times)*1e3:.2f},{phases}")
    return rows


if __name__ == "__main__":
    print("\n".join(run(sys.argv[1] if len(sys.argv) > 1 else "small")))
