"""Benchmark harness: one module per paper table/figure + framework benches.

``PYTHONPATH=src python -m benchmarks.run [--scale tiny|small|large]
[--only table1,...]``  prints ``name,...`` CSV rows per bench.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (batch_matching, fig2_bfs_iters, fig35_speedups, perf_matcher,
               roofline, serving, sharded_matching, table1_variants,
               table2_hardest, table_init, table_router)

BENCHES = {
    "table1": table1_variants.run,     # paper Table 1
    "table2": table2_hardest.run,      # paper Table 2
    "fig2": fig2_bfs_iters.run,        # paper Figure 2
    "fig35": fig35_speedups.run,       # paper Figures 3-5
    "router": table_router.run,        # framework integration (DESIGN §4)
    "init": table_init.run,            # KS vs cheap init (beyond-paper)
    "perf_matcher": perf_matcher.run,  # matcher hillclimb (docs/architecture.md)
    "roofline": roofline.run,          # roofline table (from dry-run artifacts)
    "batch": batch_matching.run,       # match_many serving throughput
    "sharded": sharded_matching.run,   # ShardedMatcher vs single-device sweep
    "serving": serving.run,            # MatchingService open-loop load sweep
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "large"])
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)
    failures = 0
    for name, fn in BENCHES.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(args.scale)
            print("\n".join(rows), flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the harness going; report at exit
            import traceback
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
