"""Benchmark harness: one module per paper table/figure + framework benches.

``PYTHONPATH=src python -m benchmarks.run [--scale tiny|small|large]
[--only table1,...]``  prints ``name,...`` CSV rows per bench.

``--json PATH`` additionally records every bench's rows (plus backend/scale
metadata) as a JSON artifact — the schema behind the committed perf baseline
``BENCH_PR7.json`` (``BENCH_PR5.json`` is the prior envelope, kept for
history).  With ``--baseline BASE`` (and BASE present on disk) the
run becomes a perf gate: for the benches in :data:`REGRESSION_BENCHES` each
row's machine-portable ``rel`` column is compared against the baseline row
with the same identity, and the harness exits non-zero on a
>``--tolerance`` (default 20%) regression.

``--update-baseline PATH`` *regenerates* a committed baseline instead of
gating against one: the gated benches re-run ``--runs`` times and each gated
row's ``rel`` is written as the **max envelope** over the runs (the same
discipline the earlier hand-assembled artifacts followed, now mechanical —
never hand-edit a baseline again).  ``--list`` prints the registered benches
(including ``autotune``, so block-size sweeps run through this harness too).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import (autotune, batch_matching, corpus, fig2_bfs_iters,
               fig35_speedups, perf_matcher, perf_smoke, roofline, serving,
               sharded_matching, table1_variants, table2_hardest, table_init,
               table_router)

BENCHES = {
    "table1": table1_variants.run,     # paper Table 1
    "table2": table2_hardest.run,      # paper Table 2
    "fig2": fig2_bfs_iters.run,        # paper Figure 2
    "fig35": fig35_speedups.run,       # paper Figures 3-5
    "router": table_router.run,        # framework integration (DESIGN §4)
    "init": table_init.run,            # KS vs cheap init (beyond-paper)
    "perf_matcher": perf_matcher.run,  # matcher hillclimb (docs/architecture.md)
    "perf_smoke": perf_smoke.run,      # level-sweep microbench (perf gate)
    "autotune": autotune.run,          # fused-kernel block_edges sweep
    "roofline": roofline.run,          # roofline table (from dry-run artifacts)
    "batch": batch_matching.run,       # match_many serving throughput
    "sharded": sharded_matching.run,   # ShardedMatcher vs single-device sweep
    "serving": serving.run,            # MatchingService open-loop load sweep
    "corpus": corpus.run,              # per-family dirop win/loss + heuristic gate
}

# row sets that feed the --baseline regression gate.  Gated rows must carry
# a `rel` column: time relative to the same-host jnp path, portable across
# machine speeds (absolute ms would flake on slower runners) — and only the
# aggregated sets are gated; per-graph sub-ms detail rows are too noisy.
# corpus.heuristic rows are deterministic modelled rels (no timing at all),
# so an alpha/beta heuristic regression fails the gate exactly like a perf
# regression — run that bench with a much tighter --tolerance than the
# timing-based perf_smoke sets (CI uses separate --only invocations).
# serving.overload_summary gates the overload posture (loss rate past
# saturation as rel); it stays dormant against baselines that predate it
# (no matching rows -> skipped) until the baseline artifact is refreshed.
REGRESSION_BENCHES = ("perf_smoke", "corpus", "serving")
GATED_SETS = ("perf_smoke.sweep_summary", "perf_smoke.solve",
              "corpus.heuristic", "serving.overload_summary")

SCHEMA = "repro-bench/1"


def _records(rows):
    """Bench rows -> (set_name, record) pairs.

    A bench may emit several CSV sections, each opened by its own header
    line (``set_name,col,...``); a header is any row whose trailing field is
    not numeric.  Comment rows (``# ...``) are skipped.
    """
    out = []
    header = None
    for row in rows:
        if row.startswith("#"):
            continue
        parts = row.split(",")
        try:
            float(parts[-1])
        except ValueError:
            header = parts
            continue
        if header is None or len(parts) != len(header):
            continue
        out.append((header[0], dict(zip(header[1:], parts[1:]))))
    return out


def _rel_index(payload, bench):
    """{row identity -> rel} over the gated sets of one bench's rows."""
    out = {}
    for set_name, rec in _records(payload.get("benches", {}).get(bench, [])):
        if set_name not in GATED_SETS or "rel" not in rec:
            continue
        try:
            out[_row_key(set_name, rec)] = float(rec["rel"])
        except ValueError:
            continue
    return out


def _row_key(set_name: str, rec: dict):
    """The gate's row identity: everything but the measured columns."""
    return (set_name,) + tuple(sorted(
        (k, v) for k, v in rec.items()
        if k not in ("ms", "geomean_ms", "rel")))


def envelope_rows(rows_runs):
    """Merge repeated runs of one bench into a max-rel envelope.

    The first run's rows are the template (headers, detail rows, ms values);
    every gated row's ``rel`` — always the trailing field — is replaced by
    the maximum over all runs for that row identity.  Baselines committed
    this way absorb run-to-run noise without a human editing JSON.
    """
    maxima = {}
    for rows in rows_runs:
        for set_name, rec in _records(rows):
            if set_name in GATED_SETS and "rel" in rec:
                try:
                    rel = float(rec["rel"])
                except ValueError:
                    continue
                key = _row_key(set_name, rec)
                maxima[key] = max(maxima.get(key, rel), rel)
    out, header = [], None
    for row in rows_runs[0]:
        parts = row.split(",")
        if row.startswith("#"):
            out.append(row)
            continue
        try:
            float(parts[-1])
        except ValueError:
            header = parts
            out.append(row)
            continue
        if (header and header[0] in GATED_SETS
                and header[-1] == "rel" and len(parts) == len(header)):
            key = _row_key(header[0], dict(zip(header[1:], parts[1:])))
            if key in maxima:
                parts[-1] = f"{maxima[key]:.3f}"
                row = ",".join(parts)
        out.append(row)
    return out


def check_regressions(baseline: dict, payload: dict, tolerance: float):
    """Gated rows regressed by more than ``tolerance`` vs the baseline.

    A baseline with gated rows that matches NOTHING in the new run is itself
    a failure — renamed paths/configs (or a backend change) would otherwise
    turn the gate vacuous and CI silently green.
    """
    failures = []
    for bench in REGRESSION_BENCHES:
        if bench not in payload.get("benches", {}):
            continue          # deselected via --only, not a vacuous gate
        old = _rel_index(baseline, bench)
        new = _rel_index(payload, bench)
        matched = old.keys() & new.keys()
        if old and not matched:
            failures.append(
                f"{bench}: 0 of {len(old)} baseline row identities match "
                f"this run (renamed sets/paths, dropped rel column, or "
                f"backend drift?) — refresh the baseline artifact instead "
                f"of letting the gate go vacuous")
            continue
        for key in sorted(old.keys() - new.keys()):
            # a vanished row could hide an unbounded regression on that path
            failures.append(
                f"{bench}: baseline row {key[0]} {dict(key[1:])} missing "
                f"from this run — renamed/removed paths need a baseline "
                f"refresh, not a silently narrower gate")
        for key in matched:
            if new[key] > old[key] * (1.0 + tolerance):
                failures.append(
                    f"{bench}: {key[0]} {dict(key[1:])} rel "
                    f"{old[key]:.3f} -> {new[key]:.3f} "
                    f"(> {tolerance:.0%} regression)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "large"])
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write the run's rows as a JSON artifact")
    ap.add_argument("--baseline", default="",
                    help="prior --json artifact to gate regressions against "
                         "(skipped when the file does not exist)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed rel-slowdown before the gate fails")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benches and exit")
    ap.add_argument("--update-baseline", default="",
                    help="re-run the gated benches --runs times and write "
                         "this baseline artifact with the max-rel envelope")
    ap.add_argument("--runs", type=int, default=3,
                    help="runs folded into the --update-baseline envelope")
    args = ap.parse_args()
    if args.list:
        for name, fn in BENCHES.items():
            doc = (fn.__module__.replace("benchmarks.", "")
                   + (" [gated]" if name in REGRESSION_BENCHES else ""))
            print(f"{name:14s} {doc}")
        return
    only = set(args.only.split(",")) if args.only else set(BENCHES)
    failures = 0
    results = {}
    for name, fn in BENCHES.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn(args.scale)
            results[name] = rows
            print("\n".join(rows), flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the harness going; report at exit
            import traceback
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
            failures += 1

    if args.update_baseline and not failures:
        import jax
        envelopes = dict(results)
        for bench in REGRESSION_BENCHES:
            if bench not in results:
                continue
            runs = [results[bench]]
            for i in range(max(0, args.runs - 1)):
                print(f"# {bench} envelope run {i + 2}/{args.runs}",
                      flush=True)
                runs.append(BENCHES[bench](args.scale))
            envelopes[bench] = envelope_rows(runs)
        payload = {"schema": SCHEMA, "backend": jax.default_backend(),
                   "scale": args.scale,
                   "note": (f"max-rel envelope over {args.runs} runs "
                            f"(benchmarks/run.py --update-baseline); gated "
                            f"sets: {', '.join(GATED_SETS)}"),
                   "benches": envelopes}
        with open(args.update_baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote baseline {args.update_baseline}", flush=True)

    if args.json or args.baseline:      # the gate must not no-op without --json
        import jax
        payload = {"schema": SCHEMA, "backend": jax.default_backend(),
                   "scale": args.scale, "benches": results}
        regressions = []
        if args.baseline and os.path.exists(args.baseline):
            with open(args.baseline) as f:
                baseline = json.load(f)
            regressions = check_regressions(baseline, payload,
                                            args.tolerance)
        elif args.baseline:
            # absence is allowed (bootstrap) but must never be silent: a
            # deleted/renamed baseline would otherwise green-light CI with
            # the gate quietly doing nothing
            print(f"# BASELINE MISSING: {args.baseline} not found — "
                  f"regression gate SKIPPED, commit a baseline artifact "
                  f"to arm it", flush=True)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"# wrote {args.json}", flush=True)
        for r in regressions:
            print(f"# REGRESSION {r}", flush=True)
        failures += len(regressions)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
