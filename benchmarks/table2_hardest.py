"""Paper Table 2: per-instance runtimes of the best variant
(APFB-GPUBFS-WR-CT, as in the paper) vs sequential HK / PFP / HK-C,
original + permuted instances."""
from __future__ import annotations

from typing import List

from repro.core import MatcherConfig
from .common import prepared_instances, time_matcher, time_sequential

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    rows = ["table2.set,instance,ours_ms,HK_ms,PFP_ms,PR_ms,HKC_ms,"
            "speedup_vs_best_seq"]
    for rcp in (False, True):
        label = "RCP" if rcp else "orig"
        for name, (g, cm0, rm0) in prepared_instances(scale, rcp).items():
            t, st = time_matcher(g, BEST, cm0, rm0, repeat=2)
            seq = time_sequential(g, cm0.copy(), rm0.copy())
            best_seq = min(seq.values())
            rows.append(
                f"{label},{name},{t*1e3:.2f},{seq['HK']*1e3:.2f},"
                f"{seq['PFP']*1e3:.2f},{seq['PR']*1e3:.2f},"
                f"{seq['HK-C']*1e3:.2f},{best_seq/t:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
