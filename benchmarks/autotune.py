"""§Autotune the fused frontier kernel's ``block_edges`` per size bucket.

The paper's MT/CT thread-geometry knob became the edge-tile size on TPU
(``default_block_edges``: CT 4096 / MT 512).  This sweep times ONE fused
level sweep per candidate tile on each canonical edge bucket and reports the
argmin; apply a winner via ``MatcherConfig(pallas_block_edges=...)``.

    python -m benchmarks.autotune [tiny|small|large] [--json PATH]

``--json`` records ``{nnz_pad: best_block_edges}`` (plus host metadata) so a
deployment can pin its tuned geometry next to its serving config.
"""
from __future__ import annotations

import json
import sys
from typing import List

import jax

from repro.graphs import random_bipartite
from repro.kernels.frontier_expand import (frontier_expand_fused,
                                           resolve_interpret)
from .common import time_call
from .perf_smoke import _sweep_state

_BUCKETS = {          # nnz_pad -> (nc, avg_deg) of the probe graph
    "tiny": [(2048, (512, 3.0))],
    "small": [(4096, (1024, 3.0)), (16384, (4096, 3.5))],
    "large": [(16384, (4096, 3.5)), (65536, (16384, 3.5)),
              (262144, (65536, 3.5))],
}
_CANDIDATES = (256, 512, 1024, 2048, 4096, 8192)


def run(scale: str = "tiny") -> List[str]:
    backend = jax.default_backend()
    interpret = resolve_interpret(None)
    rows = ["autotune,backend,nnz_pad,block_edges,ms,best"]
    best = {}
    for nnz_pad, (nc, deg) in _BUCKETS[scale]:
        g = random_bipartite(nc, nc, deg, seed=nc, pad_to=nnz_pad)
        ecol, cadj, bfs, root, rmj = _sweep_state(g)
        timed = []
        for blk in _CANDIDATES:
            if blk > nnz_pad:
                continue
            fn = lambda: jax.block_until_ready(frontier_expand_fused(
                ecol, cadj, bfs, root, rmj, 2, block_edges=blk,
                interpret=interpret))
            fn()                                   # compile (not timed)
            timed.append((time_call(fn, repeat=3), blk))
        t_best, blk_best = min(timed)
        best[nnz_pad] = blk_best
        for t, blk in timed:
            rows.append(f"autotune,{backend},{nnz_pad},{blk},{t*1e3:.3f},"
                        f"{'*' if blk == blk_best else ''}")
    rows.append("# autotune.best," + ",".join(
        f"{k}:{v}" for k, v in sorted(best.items())))
    return rows


def main() -> None:
    args = sys.argv[1:]
    scale = args[0] if args and not args[0].startswith("--") else "tiny"
    rows = run(scale)
    print("\n".join(rows))
    if "--json" in args:
        path = args[args.index("--json") + 1]
        table = {}
        for r in rows:
            parts = r.split(",")
            if parts[0] == "autotune" and parts[-1] == "*":
                table[int(parts[2])] = int(parts[3])
        payload = {"schema": "repro-autotune/1",
                   "backend": jax.default_backend(), "scale": scale,
                   "block_edges": table}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
