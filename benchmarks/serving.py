"""Open-loop serving sweep: latency percentiles + occupancy vs offered load.

Replays a Poisson arrival trace of same-family graphs at increasing offered
rates against a warmed :class:`repro.serving.MatchingService` and reports,
per load level: p50/p99 end-to-end latency, batch occupancy, device
dispatches vs the naive 1-dispatch-per-request loop, and the flush-reason
mix.  The dispatch column is the acceptance check for the scheduler: the
batched path issues exactly ONE device dispatch per flushed bucket, so
``dispatches`` must be <= ``requests`` (and shrinks as load grows and
batches fill).

A second, past-saturation section drives offered load well beyond the
sweep's top rate against a *bounded* service (``max_queue`` + per-request
``deadline_s``) and reports the overload posture: shed rate, deadline-miss
rate, and p99 latency of the requests that were actually served.  Its
``serving.overload_summary`` row carries the loss rate as the portable
``rel`` column so overload behaviour is regression-gated by
``benchmarks/run.py --baseline`` exactly like perf.

    PYTHONPATH=src python -m benchmarks.serving [--scale tiny]
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.graphs import random_bipartite
from repro.matching import MatcherConfig
from repro.matching.device_csr import bucket_nnz
from repro.serving import (Bucketizer, MatchingService, QueueFullError,
                           SizeBucket, percentile)

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    n, deg, requests = {"tiny": (192, 3.0, 48),
                        "small": (1024, 4.0, 128),
                        "large": (4096, 4.0, 256)}[scale]
    rates = {"tiny": (100.0, 500.0, 2500.0),
             "small": (50.0, 250.0, 1000.0),
             "large": (25.0, 100.0, 400.0)}[scale]
    v = 1 << max(8, int(np.ceil(np.log2(n))))
    bucket = SizeBucket(v, v, bucket_nnz(int(v * deg * 2)))
    pool = [random_bipartite(n, n, deg, seed=s) for s in range(16)]
    rng = np.random.default_rng(7)

    rows = ["serving.rate_rps,requests,p50_ms,p99_ms,occupancy,dispatches,"
            "req_per_dispatch,naive_dispatches,full,deadline,drain,"
            "compile_misses"]
    for rate in rates:
        service = MatchingService(bucketizer=Bucketizer((bucket,)),
                                  config=BEST, warm_start="cheap",
                                  max_batch=8, max_delay_ms=2.0)
        service.warm_up()                      # AOT: traffic never compiles
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
        t0 = time.perf_counter()
        futures = []
        for i in range(requests):
            lag = t0 + arrivals[i] - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            futures.append(service.submit(pool[i % len(pool)]))
        results = [f.result(timeout=300) for f in futures]
        service.drain()
        snap = service.metrics.snapshot()
        service.close()
        lat = [r.latency_s for r in results]
        p50 = percentile(lat, 50) * 1e3
        p99 = percentile(lat, 99) * 1e3
        dispatches = snap["dispatches"]
        assert dispatches <= requests, (dispatches, requests)
        rows.append(
            f"{rate:g},{requests},{p50:.2f},{p99:.2f},"
            f"{snap['occupancy']:.2f},{dispatches},"
            f"{requests / max(1, dispatches):.2f},{requests},"
            f"{snap['flushes_full']},{snap['flushes_deadline']},"
            f"{snap['flushes_drain']},{snap['compile_misses']}")

    rows += overload_rows(bucket, pool, requests, rates[-1] * 4, rng)
    return rows


def overload_rows(bucket, pool, requests: int, rate: float, rng) -> List[str]:
    """Past-saturation posture: offered load ~4x the sweep's top rate at a
    *bounded* service (``max_queue`` backpressure + per-request deadline).

    The detail row reports shed rate, deadline-miss rate, and p99 latency of
    the requests actually served; the ``serving.overload_summary`` row
    carries the total loss rate (shed + deadline misses, over offered) as
    the machine-portable ``rel`` the regression gate watches — measured
    values stay out of the summary's identity columns so baseline rows keep
    matching across runs.
    """
    service = MatchingService(bucketizer=Bucketizer((bucket,)),
                              config=BEST, warm_start="cheap",
                              max_batch=8, max_delay_ms=2.0,
                              max_queue=2 * 8, shed_policy="reject-newest")
    service.warm_up()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    t0 = time.perf_counter()
    futures = []
    shed = 0
    for i in range(requests):
        lag = t0 + arrivals[i] - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        try:
            futures.append(service.submit(pool[i % len(pool)],
                                          deadline_s=0.5))
        except QueueFullError:
            shed += 1
    service.drain()
    served = [f.result() for f in futures if f.exception(timeout=300) is None]
    snap = service.metrics.snapshot()
    service.close()
    misses = snap["deadline_misses"]
    loss = (shed + misses) / requests
    p99 = (percentile([r.latency_s for r in served], 99) * 1e3
           if served else float("nan"))
    return [
        "serving.overload,requests,served,shed_rate,deadline_miss_rate,"
        "p99_served_ms",
        f"{rate:g},{requests},{len(served)},{shed / requests:.3f},"
        f"{misses / requests:.3f},{p99:.2f}",
        "serving.overload_summary,requests,rel",
        f"{rate:g},{requests},{loss:.3f}",
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "large"])
    print("\n".join(run(ap.parse_args().scale)))
