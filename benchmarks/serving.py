"""Open-loop serving sweep: latency percentiles + occupancy vs offered load.

Replays a Poisson arrival trace of same-family graphs at increasing offered
rates against a warmed :class:`repro.serving.MatchingService` and reports,
per load level: p50/p99 end-to-end latency, batch occupancy, device
dispatches vs the naive 1-dispatch-per-request loop, and the flush-reason
mix.  The dispatch column is the acceptance check for the scheduler: the
batched path issues exactly ONE device dispatch per flushed bucket, so
``dispatches`` must be <= ``requests`` (and shrinks as load grows and
batches fill).

    PYTHONPATH=src python -m benchmarks.serving [--scale tiny]
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.graphs import random_bipartite
from repro.matching import MatcherConfig
from repro.matching.device_csr import bucket_nnz
from repro.serving import (Bucketizer, MatchingService, SizeBucket,
                           percentile)

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    n, deg, requests = {"tiny": (192, 3.0, 48),
                        "small": (1024, 4.0, 128),
                        "large": (4096, 4.0, 256)}[scale]
    rates = {"tiny": (100.0, 500.0, 2500.0),
             "small": (50.0, 250.0, 1000.0),
             "large": (25.0, 100.0, 400.0)}[scale]
    v = 1 << max(8, int(np.ceil(np.log2(n))))
    bucket = SizeBucket(v, v, bucket_nnz(int(v * deg * 2)))
    pool = [random_bipartite(n, n, deg, seed=s) for s in range(16)]
    rng = np.random.default_rng(7)

    rows = ["serving.rate_rps,requests,p50_ms,p99_ms,occupancy,dispatches,"
            "req_per_dispatch,naive_dispatches,full,deadline,drain,"
            "compile_misses"]
    for rate in rates:
        service = MatchingService(bucketizer=Bucketizer((bucket,)),
                                  config=BEST, warm_start="cheap",
                                  max_batch=8, max_delay_ms=2.0)
        service.warm_up()                      # AOT: traffic never compiles
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
        t0 = time.perf_counter()
        futures = []
        for i in range(requests):
            lag = t0 + arrivals[i] - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            futures.append(service.submit(pool[i % len(pool)]))
        results = [f.result(timeout=300) for f in futures]
        service.drain()
        snap = service.metrics.snapshot()
        service.close()
        lat = [r.latency_s for r in results]
        p50 = percentile(lat, 50) * 1e3
        p99 = percentile(lat, 99) * 1e3
        dispatches = snap["dispatches"]
        assert dispatches <= requests, (dispatches, requests)
        rows.append(
            f"{rate:g},{requests},{p50:.2f},{p99:.2f},"
            f"{snap['occupancy']:.2f},{dispatches},"
            f"{requests / max(1, dispatches):.2f},{requests},"
            f"{snap['flushes_full']},{snap['flushes_deadline']},"
            f"{snap['flushes_drain']},{snap['compile_misses']}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "small", "large"])
    print("\n".join(run(ap.parse_args().scale)))
