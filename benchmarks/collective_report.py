"""Inspection tool: collective traffic, for both sides of the repo.

Dry-run mode (default) — top collective contributors per LM dry-run cell:

    PYTHONPATH=src python -m benchmarks.collective_report [pattern]

Matcher mode — price the ShardedMatcher's one-pmin-per-BFS-level collective
against the local per-shard expansion sweep (docs/architecture.md,
"ShardedMatcher"): per instance, measured total BFS levels x the ring
all-reduce bytes ``2*(D-1)/D * 4*(nr+1)`` per link, vs the local
``O(nnz/D)`` edge traffic per level:

    PYTHONPATH=src python -m benchmarks.collective_report --matcher [D]
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List


def run(pattern: str = "") -> List[str]:
    """Largest collective ops (shape x trip-count = bytes) recorded in
    experiments/dryrun/*.json."""
    rows = ["collectives.cell,gib,op"]
    for fn in sorted(glob.glob("experiments/dryrun/*.json")):
        if pattern and pattern not in fn:
            continue
        rec = json.load(open(fn))
        if rec.get("status") != "ok" or not rec.get("collective_top"):
            continue
        cell = os.path.basename(fn)[:-5]
        for k, v in rec["collective_top"][:3]:
            rows.append(f"{cell},{v / 2**30:.1f},{k[:90]}")
    return rows


def matcher_rows(ndev: int = 8, scale: str = "tiny") -> List[str]:
    """ShardedMatcher collective model on the paper instance suite.

    ``levels`` is measured (instrumented per-level re-execution, same as
    benchmarks/fig2_bfs_iters.py); bytes are the analytic ring-all-reduce /
    edge-sweep volumes.  ``pmin_pct`` is the collective share of total
    traffic — the scale-out headroom of the edge-partitioned design.
    """
    from benchmarks.fig2_bfs_iters import instrumented_phases
    from repro.graphs import instance_sets
    from repro.matching.device_csr import per_shard_nnz

    rows = ["sharded_collectives.instance,nr,levels,devices,"
            "pmin_kib_per_level,pmin_mib_total,local_mib_per_dev,pmin_pct"]
    for name, g in instance_sets(scale).items():
        levels = sum(instrumented_phases(g, "apfb"))
        per_level = 2 * (ndev - 1) / ndev * 4 * (g.nr + 1)   # ring, bytes/link
        pmin_total = levels * per_level
        # local sweep: ecol + cadj reads and one proposal write per edge/level
        # over each device's bucketed shard (mirrors DeviceCSR.shard padding)
        edges_per_dev = per_shard_nnz(g.nnz_pad, ndev)
        local_total = levels * 3 * 4 * edges_per_dev
        rows.append(
            f"{name},{g.nr},{levels},{ndev},{per_level / 2**10:.1f},"
            f"{pmin_total / 2**20:.2f},{local_total / 2**20:.2f},"
            f"{100 * pmin_total / (pmin_total + local_total):.1f}")
    return rows


if __name__ == "__main__":
    args = sys.argv[1:]
    if args and args[0] == "--matcher":
        print("\n".join(matcher_rows(int(args[1]) if len(args) > 1 else 8)))
    else:
        print("\n".join(run(args[0] if args else "")))
