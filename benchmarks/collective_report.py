"""Inspection tool: top collective contributors per dry-run cell.

    PYTHONPATH=src python -m benchmarks.collective_report [pattern]

Prints the largest collective ops (shape x trip-count = bytes) recorded in
experiments/dryrun/*.json — the profile §Perf iterations are driven by.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List


def run(pattern: str = "") -> List[str]:
    rows = ["collectives.cell,gib,op"]
    for fn in sorted(glob.glob("experiments/dryrun/*.json")):
        if pattern and pattern not in fn:
            continue
        rec = json.load(open(fn))
        if rec.get("status") != "ok" or not rec.get("collective_top"):
            continue
        cell = os.path.basename(fn)[:-5]
        for k, v in rec["collective_top"][:3]:
            rows.append(f"{cell},{v / 2**30:.1f},{k[:90]}")
    return rows


if __name__ == "__main__":
    print("\n".join(run(sys.argv[1] if len(sys.argv) > 1 else "")))
