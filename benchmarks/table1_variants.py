"""Paper Table 1: geometric-mean runtime of the eight matcher variants
(APFB/APsB x GPUBFS/GPUBFS-WR x MT/CT) on the original and RCP sets."""
from __future__ import annotations

from typing import List

from repro.core import VARIANTS
from .common import geomean, prepared_instances, time_matcher


def run(scale: str = "tiny") -> List[str]:
    rows = ["table1.set,variant,geomean_ms,total_phases"]
    for rcp in (False, True):
        label = "RCP_S1" if rcp else "O_S1"
        insts = prepared_instances(scale, rcp)
        for cfg in VARIANTS:
            times, phases = [], 0
            for name, (g, cm0, rm0) in insts.items():
                t, st = time_matcher(g, cfg, cm0, rm0, repeat=2)
                times.append(t)
                phases += st["phases"]
            rows.append(f"{label},{cfg.name},{geomean(times)*1e3:.2f},{phases}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
