"""Initialization-quality study: cheap matching vs Karp-Sipser (beyond-paper).

The paper initializes everything with cheap matching; KS peeling leaves
fewer unmatched vertices, which cuts the matcher's phase count.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import (MatcherConfig, cheap_matching_jax, karp_sipser_jax,
                        maximum_cardinality, maximum_matching)
from repro.graphs import instance_sets

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    rows = ["init.instance,opt,cheap_card,ks_card,"
            "phases_from_cheap,phases_from_ks"]
    for name, g in instance_sets(scale).items():
        opt = maximum_cardinality(g)
        c_cm, c_rm = cheap_matching_jax(g)
        k_cm, k_rm = karp_sipser_jax(g)
        _, _, st_c = maximum_matching(g, BEST, c_cm, c_rm)
        _, _, st_k = maximum_matching(g, BEST, k_cm, k_rm)
        rows.append(f"{name},{opt},{(c_cm >= 0).sum()},{(k_cm >= 0).sum()},"
                    f"{st_c['phases']},{st_k['phases']}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
