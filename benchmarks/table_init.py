"""Initialization-quality study: cheap matching vs Karp-Sipser (beyond-paper).

The paper initializes everything with cheap matching; KS peeling leaves
fewer unmatched vertices, which cuts the matcher's phase count.  Uses the
warm-start registry so init + solve run as one compiled program per variant.
"""
from __future__ import annotations

from typing import List

from repro.core import maximum_cardinality
from repro.graphs import instance_sets
from repro.matching import DeviceCSR, Matcher, MatcherConfig

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    rows = ["init.instance,opt,cheap_card,ks_card,"
            "phases_from_cheap,phases_from_ks"]
    for name, g in instance_sets(scale).items():
        opt = maximum_cardinality(g)
        graph = DeviceCSR.from_host(g)
        cards, phases = {}, {}
        for ws in ("cheap", "karp_sipser"):
            matcher = Matcher(BEST, warm_start=ws)
            state0 = matcher.init(graph)
            cards[ws] = int(state0.cardinality)
            phases[ws] = int(matcher.run(graph, state0).phases)
        rows.append(f"{name},{opt},{cards['cheap']},{cards['karp_sipser']},"
                    f"{phases['cheap']},{phases['karp_sipser']}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
