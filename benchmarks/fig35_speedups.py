"""Paper Figures 3-5: speedup profiles and performance profiles of the best
variant vs the sequential algorithms, original + RCP sets."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import MatcherConfig
from .common import geomean, prepared_instances, time_matcher, time_sequential

BEST = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")


def run(scale: str = "tiny") -> List[str]:
    rows = ["fig35.set,instance,speedup_vs_HK,speedup_vs_PFP,speedup_vs_HKC"]
    summary = []
    for rcp in (False, True):
        label = "RCP" if rcp else "orig"
        speeds = {"HK": [], "PFP": [], "HK-C": []}
        for name, (g, cm0, rm0) in prepared_instances(scale, rcp).items():
            t, _ = time_matcher(g, BEST, cm0, rm0, repeat=2)
            seq = time_sequential(g, cm0.copy(), rm0.copy())
            for k in speeds:
                speeds[k].append(seq[k] / t)
            rows.append(f"{label},{name},{seq['HK']/t:.2f},"
                        f"{seq['PFP']/t:.2f},{seq['HK-C']/t:.2f}")
        # profile: fraction of instances with speedup >= 1 (paper's fig3 y@x=0)
        frac = {k: float(np.mean(np.asarray(v) >= 1.0))
                for k, v in speeds.items()}
        summary.append(
            f"{label},GEOMEAN,{geomean(speeds['HK']):.2f},"
            f"{geomean(speeds['PFP']):.2f},{geomean(speeds['HK-C']):.2f}")
        summary.append(
            f"{label},FRAC_FASTER,{frac['HK']:.2f},{frac['PFP']:.2f},"
            f"{frac['HK-C']:.2f}")
    return rows + summary


if __name__ == "__main__":
    print("\n".join(run()))
