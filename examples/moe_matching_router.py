"""The paper's technique as a framework feature: maximum-cardinality
matching for MoE token->expert assignment, vs the standard greedy router.

    PYTHONPATH=src python examples/moe_matching_router.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import ShapeCell, make_inputs
from repro.models import build_model
from repro.moe import route_matching, route_topk, router_stats


def router_comparison():
    print("=== router comparison under expert contention ===")
    T, E, k = 2048, 16, 4
    C = int(1.0 * T * k / E)
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, E)) \
        + jnp.linspace(2.0, 0.0, E)[None]        # skewed -> hot experts
    for name, fn in (("greedy top-k", route_topk),
                     ("matching (paper)", route_matching)):
        assign, slot, p = jax.jit(lambda l, fn=fn: fn(l, k, C))(logits)
        st = router_stats(np.asarray(assign), k)
        print(f"  {name:18s} dropped {st['drop_rate']*100:5.2f}% of "
              f"{st['demand']} (token,expert) assignments")


def end_to_end_moe():
    print("=== dbrx-style MoE forward with both routers ===")
    batch = make_inputs(get_config("dbrx-132b", smoke=True),
                        ShapeCell("t", 64, 2, "train"))
    for router in ("topk", "matching"):
        cfg = get_config("dbrx-132b", smoke=True, router=router,
                         capacity_factor=0.75)   # tight capacity
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        logits, aux = model.forward(params, batch)
        print(f"  router={router:9s} lb_loss={float(aux['lb_loss']):.4f} "
              f"logits {tuple(logits.shape)} finite="
              f"{bool(np.isfinite(np.asarray(logits, np.float32)).all())}")


if __name__ == "__main__":
    router_comparison()
    end_to_end_moe()
