"""Quickstart: maximum cardinality bipartite matching with the paper's
GPU-style algorithms (APFB / APsB) on the device-resident API.

    PYTHONPATH=src python examples/quickstart.py   (or `pip install -e .`)
"""
import numpy as np

from repro.core import hopcroft_karp, validate_matching
from repro.graphs import kron_graph, random_bipartite
from repro.matching import (DeviceCSR, Matcher, MatcherConfig, VARIANTS,
                            compile_cache_info, match_many)


def main():
    # a power-law bipartite graph (kron_g500-style, as in the paper's suite)
    g = kron_graph(scale=12, edge_factor=8, seed=1)
    print(f"graph: {g.nc} cols, {g.nr} rows, {g.nnz} edges")

    # upload once; the graph is a pytree and stays on device from here on
    graph = DeviceCSR.from_host(g)

    # the paper's winning variant: APFB + GPUBFS-WR + CT, warm-started with
    # cheap matching — init + solve fuse into ONE compiled program
    best = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
    matcher = Matcher(best, warm_start="cheap")
    state = matcher.run(graph)
    stats = matcher.stats(state).as_dict()          # first host sync
    cmatch, rmatch = state.to_host()
    card = validate_matching(g, cmatch, rmatch)
    print(f"{best.name}: |M| = {card} in {stats['phases']} phases "
          f"({stats['fallbacks']} fallbacks)")

    # cross-check against sequential Hopcroft-Karp (the paper's baseline)
    cm_hk, _ = hopcroft_karp(g)
    assert card == int((cm_hk >= 0).sum())
    print("matches sequential Hopcroft-Karp cardinality: OK")

    # all eight variants of Table 1 share the uploaded graph
    for cfg in VARIANTS:
        st = Matcher(cfg, warm_start="cheap").run(graph)
        print(f"  {cfg.name:28s} phases={int(st.phases):3d} "
              f"card={int(st.cardinality)}")

    # batched serving: 8 independent graphs, one vmap-compiled dispatch
    batch = DeviceCSR.stack([
        DeviceCSR.from_host(random_bipartite(512, 512, 3.0, seed=s,
                                             pad_to=2048))
        for s in range(8)])
    many = match_many(batch, best, warm_start="karp_sipser")
    print("match_many cardinalities:", np.asarray(many.cardinality).tolist())
    print("compiled programs cached:", compile_cache_info()["entries"])


if __name__ == "__main__":
    main()
