"""Quickstart: maximum cardinality bipartite matching with the paper's
GPU-style algorithms (APFB / APsB) in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (MatcherConfig, VARIANTS, cheap_matching_jax,
                        hopcroft_karp, maximum_matching, validate_matching)
from repro.graphs import kron_graph, random_bipartite


def main():
    # a power-law bipartite graph (kron_g500-style, as in the paper's suite)
    g = kron_graph(scale=12, edge_factor=8, seed=1)
    print(f"graph: {g.nc} cols, {g.nr} rows, {g.nnz} edges")

    # the common warm start: parallel cheap matching
    cm0, rm0 = cheap_matching_jax(g)
    print(f"cheap matching: {(cm0 >= 0).sum()} pairs")

    # the paper's winning variant: APFB + GPUBFS-WR + CT
    best = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
    cmatch, rmatch, stats = maximum_matching(g, best, cm0, rm0)
    card = validate_matching(g, cmatch, rmatch)
    print(f"{best.name}: |M| = {card} in {stats['phases']} phases "
          f"({stats['fallbacks']} fallbacks)")

    # cross-check against sequential Hopcroft-Karp (the paper's baseline)
    cm_hk, rm_hk = hopcroft_karp(g)
    assert card == int((cm_hk >= 0).sum())
    print("matches sequential Hopcroft-Karp cardinality: OK")

    # all eight variants of Table 1
    for cfg in VARIANTS:
        _, _, st = maximum_matching(g, cfg, cm0, rm0)
        print(f"  {cfg.name:28s} phases={st['phases']:3d} "
              f"card={st['cardinality']}")


if __name__ == "__main__":
    main()
