"""End-to-end training driver: a ~100M-parameter decoder LM trained a few
hundred steps on the deterministic synthetic stream, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py              # ~25M, quick
    PYTHONPATH=src python examples/train_lm.py --full       # ~100M, longer

Uses the same fault-tolerant loop as ``repro.launch.train`` — kill it and
re-run: it resumes from the newest checkpoint.
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model
from repro.models.common import tree_size
from repro.optim import OptConfig, adamw_init
from repro.train import build_train_step
from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps (slow on CPU)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("h2o-danube-1.8b", smoke=True, n_layers=12,
                         d_model=640, n_heads=8, n_kv_heads=4, d_ff=1920,
                         vocab=32000, window=256)
        steps = args.steps or 200
        seq, batch = 256, 8
    else:
        cfg = get_config("h2o-danube-1.8b", smoke=True, n_layers=6,
                         d_model=320, n_heads=8, n_kv_heads=4, d_ff=960,
                         vocab=8192, window=128)
        steps = args.steps or 120
        seq, batch = 128, 8

    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    print(f"model: {tree_size(params)/1e6:.1f}M params")
    opt_cfg = OptConfig(lr=3e-3, warmup=20, weight_decay=0.01)
    opt_state, _ = adamw_init(params, specs, opt_cfg)
    step_fn = jax.jit(build_train_step(model, opt_cfg),
                      donate_argnums=(0, 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    import jax.numpy as jnp
    for step in range(start, steps):
        batch_j = {k: jnp.asarray(v)
                   for k, v in synthetic_batch(dcfg, step).items()}
        params, opt_state, m = step_fn(params, opt_state, batch_j)
        if (step + 1) % 10 == 0 or step == start:
            print(f"step {step + 1:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if (step + 1) % 50 == 0 or step + 1 == steps:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state})
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
