"""The paper's stated future work: distributed-memory matching.

Edge-partitioned APFB over a device mesh (shard_map + pmin per BFS level).
Runs on 8 simulated host devices:

    PYTHONPATH=src python examples/distributed_matching.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.core import (MatcherConfig, cheap_matching_jax,                  # noqa: E402
                        maximum_cardinality, validate_matching)
from repro.core.distributed import maximum_matching_distributed            # noqa: E402
from repro.graphs import random_bipartite                                  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    g = random_bipartite(4096, 4096, 6.0, seed=0)
    print(f"graph: {g.nc}x{g.nr}, {g.nnz} edges, "
          f"sharded over {mesh.shape['data']} devices "
          f"({g.nnz_pad // 8} edges/device)")
    cm0, rm0 = cheap_matching_jax(g)
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    cmatch, rmatch, stats = maximum_matching_distributed(
        g, mesh, cfg, cmatch0=cm0, rmatch0=rm0)
    card = validate_matching(g, cmatch, rmatch)
    opt = maximum_cardinality(g)
    print(f"distributed {stats['variant']}: |M| = {card} "
          f"(optimal {opt}) in {stats['phases']} phases")
    assert card == opt
    print("OK — one pmin collective per BFS level, state replicated, "
          "edges sharded")


if __name__ == "__main__":
    main()
