"""The paper's stated future work: distributed-memory matching.

``ShardedMatcher`` — edge-partitioned APFB over a device mesh, one ``pmin``
collective per BFS level, same solve loop as the single-device ``Matcher``
(see docs/architecture.md).  Runs on 8 simulated host devices:

    PYTHONPATH=src python examples/distributed_matching.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.core import maximum_cardinality, validate_matching               # noqa: E402
from repro.graphs import random_bipartite                                  # noqa: E402
from repro.matching import (DeviceCSR, Matcher, MatcherConfig,             # noqa: E402
                            ShardedMatcher)


def main():
    mesh = jax.make_mesh((8,), ("data",))
    g = random_bipartite(4096, 4096, 6.0, seed=0)
    graph = DeviceCSR.from_host(g).shard(mesh, "data")
    print(f"graph: {g.nc}x{g.nr}, {g.nnz} edges, "
          f"sharded over {mesh.shape['data']} devices "
          f"({graph.nnz_pad // 8} edges/device)")
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr")
    sharded = ShardedMatcher(mesh, config=cfg, warm_start="cheap")
    state = sharded.run(graph)            # warm start + solve, one program
    cmatch, rmatch = state.to_host()
    card = validate_matching(g, cmatch, rmatch)
    opt = maximum_cardinality(g)
    stats = sharded.stats(state).as_dict()
    print(f"distributed {stats['variant']}: |M| = {card} "
          f"(optimal {opt}) in {stats['phases']} phases")
    assert card == opt
    single = Matcher(cfg, warm_start="cheap").run(DeviceCSR.from_host(g))
    assert int(single.cardinality) == card
    print("OK — one pmin collective per BFS level, state replicated, "
          "edges sharded; cardinality matches the single-device Matcher")


if __name__ == "__main__":
    main()
