"""Minimal end-to-end matching service demo: warmup, a mixed burst, metrics.

Builds a :class:`repro.serving.MatchingService` over one declared size
bucket, AOT-compiles its (bucket x config x warm-start x batch) grid, fires
a burst of mixed-family graphs at it, and prints per-request stats plus the
service counters.  Runs on 4 simulated host devices so the oversize ->
ShardedMatcher admission route is exercised too:

    PYTHONPATH=src python examples/matching_service.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402

from repro.core import validate_matching                                    # noqa: E402
from repro.graphs import (grid_graph, kron_graph, random_bipartite,        # noqa: E402
                          scaled_free)
from repro.matching import DeviceCSR, Matcher, MatcherConfig               # noqa: E402
from repro.serving import Bucketizer, MatchingService, SizeBucket          # noqa: E402


def main():
    cfg = MatcherConfig(algo="apfb", kernel="gpubfs_wr", schedule="ct")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    service = MatchingService(
        bucketizer=Bucketizer((SizeBucket(256, 256, 2048),),
                              oversize="shard"),
        config=cfg, warm_start="cheap",
        max_batch=4, max_delay_ms=2.0, mesh=mesh)

    print(service.warm_up())                 # AOT: traffic never compiles

    burst = {
        "random": random_bipartite(200, 180, 3.0, seed=1),
        "kron": kron_graph(7, 6, seed=2),
        "grid": grid_graph(12),
        "free": scaled_free(150, 160, 4.0, seed=3),
        "oversize": random_bipartite(400, 400, 4.0, seed=4),   # -> sharded
    }
    futures = {name: service.submit(g) for name, g in burst.items()}

    for name, fut in futures.items():
        res = fut.result(timeout=300)
        g = burst[name]
        cm, rm = res.matching()
        assert validate_matching(g, cm, rm) == res.cardinality
        direct = Matcher(cfg, warm_start="cheap").run(
            DeviceCSR.from_host(g).bucketed())
        assert res.cardinality == int(direct.cardinality), name
        print(f"{name:>9}: route={res.route:<7} |M|={res.cardinality:4d} "
              f"batch={res.batch_size} wait={res.queue_wait_s * 1e3:6.1f} ms "
              f"latency={res.latency_s * 1e3:6.1f} ms")

    snap = service.metrics.snapshot()
    service.close()
    print(f"service: {snap['submitted']} submitted, "
          f"{snap['dispatches']} dispatches, "
          f"occupancy {snap['occupancy']:.2f}, "
          f"pad-waste {snap['pad_edge_waste']:.2f}, "
          f"compile {snap['compile_hits']}h/{snap['compile_misses']}m")
    print("OK — every request matched the direct Matcher, one dispatch "
          "per flushed bucket")


if __name__ == "__main__":
    main()
