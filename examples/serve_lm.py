"""Batched serving: prefill a batch of prompts, decode with the KV/SSM cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-2.7b]
"""
import argparse

from repro.configs import ARCH_NAMES
from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    out = run(args.arch, smoke=True, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen)
    print("generated token ids (first sequence):", out[0].tolist())


if __name__ == "__main__":
    main()
